"""Logical-axis sharding rules -> PartitionSpecs for params / batch / cache.

MaxText-style: parameters are matched by their tree path (names are stable
across the model zoo) and given PartitionSpecs built from a rule table.
Rules adapt to the mesh (axis sizes must divide the dim) and to the shape
kind (train / prefill / decode / long-decode).

Baseline layout (hillclimbed in EXPERIMENTS.md §Perf):
- batch        -> ("pod", "data")     (replicated when batch==1, long_500k)
- d_ff / heads -> "model"             (tensor parallel)
- d_model rows of big matrices -> "data"  (FSDP; gathered on use)
- vocab        -> "model"
- MoE experts  -> "data" when divisible (arctic 128/16), else d_ff/"model"
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "param_specs", "batch_specs", "cache_specs",
           "opt_state_specs", "named", "constrain"]

PyTree = Any


class ShardingRules:
    """Maps logical roles to mesh axes; override per experiment."""

    def __init__(
        self,
        mesh: Mesh,
        *,
        batch_axes: Tuple[str, ...] = ("pod", "data"),
        fsdp_axis: Optional[str] = "data",
        tp_axis: Optional[str] = "model",
        expert_axis: Optional[str] = "data",
        shard_activations_embed: bool = False,
        attn_shard_mode: str = "heads",      # heads | seq
        moe_layout: str = "none",            # none | expert_major | grid
        seq_axis=None,                       # activation seq-dim sharding
    ):
        self.mesh = mesh
        names = mesh.axis_names

        def _valid(axis):
            if isinstance(axis, tuple):
                axis = tuple(a for a in axis if a in names)
                return axis or None
            return axis if axis in names else None

        self.batch_axes = tuple(a for a in batch_axes if a in names)
        self.fsdp_axis = _valid(fsdp_axis)
        self.tp_axis = _valid(tp_axis)
        self.expert_axis = _valid(expert_axis)
        self.shard_activations_embed = shard_activations_embed
        self.attn_shard_mode = attn_shard_mode
        self.moe_layout = moe_layout
        self.seq_axis = _valid(seq_axis)

    def size(self, axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, tuple):
            n = 1
            for a in axis:
                n *= self.mesh.shape[a]
            return n
        return self.mesh.shape[axis]

    def axis_if_divides(self, axis, dim: int):
        """axis may be a name or a tuple of names (multi-axis sharding)."""
        if axis is not None and dim > 0 and dim % self.size(axis) == 0:
            return axis
        return None

    def batch_spec_axes(self, batch: int):
        """Largest prefix of batch_axes whose product divides batch."""
        out = []
        prod = 1
        for a in self.batch_axes:
            if batch % (prod * self.size(a)) == 0:
                out.append(a)
                prod *= self.size(a)
        return tuple(out) if out else None


# ---------------------------------------------------------------------------
# Param rules (path-regex -> spec builder)
# ---------------------------------------------------------------------------


def _param_rule(path: str, shape: Tuple[int, ...], r: ShardingRules) -> P:
    """Assign a spec given the param path and shape.

    Paths look like: "embed", "blocks/pos0/attn/wq/w", "tail/tail0/mlp/wi",
    "blocks/pos0/moe/wi", "decoder/self_attn/wo/w", "lm_head", ...
    Leading stacked dims (scan repeats) are never sharded.
    """
    ndim = len(shape)
    stacked = path.startswith("blocks/") or path.startswith("decoder/") \
        or path.startswith("encoder/")
    lead: Tuple[Optional[str], ...] = (None,) if stacked else ()
    body = shape[1:] if stacked else shape
    nb = len(body)

    def spec(*axes):
        return P(*(lead + axes))

    fsdp, tp = r.fsdp_axis, r.tp_axis

    # ---- embeddings / heads -------------------------------------------------
    if re.fullmatch(r".*embed", path):
        return P(r.axis_if_divides(tp, shape[0]),
                 r.axis_if_divides(fsdp, shape[1]))
    if re.fullmatch(r".*lm_head", path):
        return P(r.axis_if_divides(fsdp, shape[0]),
                 r.axis_if_divides(tp, shape[1]))

    # ---- MoE ------------------------------------------------------------------
    if "/moe/" in path:
        if path.endswith("router"):
            return spec(r.axis_if_divides(fsdp, body[0]), None)
        # wi/wg/wo: (E, D, F) or (E, F, D)
        E = body[0]
        ea = r.axis_if_divides(r.expert_axis, E)

        def minus(axis, used):
            """axis with names already used removed (no duplicate axes)."""
            if axis is None:
                return None
            used_names = set(used if isinstance(used, tuple)
                             else ([] if used is None else [used]))
            names = axis if isinstance(axis, tuple) else (axis,)
            left = tuple(a for a in names if a not in used_names)
            return left if len(left) > 1 else (left[0] if left else None)

        if path.endswith(("wi", "wg")):
            d_axis = r.axis_if_divides(minus(fsdp, ea), body[1])
            return spec(ea, d_axis, r.axis_if_divides(tp, body[2]))
        d_axis = r.axis_if_divides(minus(fsdp, ea), body[2])
        return spec(ea, r.axis_if_divides(tp, body[1]), d_axis)

    # ---- biases / norms / vectors ------------------------------------------------
    if nb <= 1:
        return spec(*([None] * nb))

    # ---- attention projections ------------------------------------------------
    if re.search(r"(attn|self_attn|cross_attn)/w[qkv]/w$", path):
        return spec(r.axis_if_divides(fsdp, body[0]),
                    r.axis_if_divides(tp, body[1]))
    if re.search(r"(attn|self_attn|cross_attn)/wo/w$", path):
        return spec(r.axis_if_divides(tp, body[0]),
                    r.axis_if_divides(fsdp, body[1]))

    # ---- MLP ----------------------------------------------------------------------
    if re.search(r"mlp/(wi|wg)$", path):
        return spec(r.axis_if_divides(fsdp, body[0]),
                    r.axis_if_divides(tp, body[1]))
    if re.search(r"mlp/wo$", path):
        return spec(r.axis_if_divides(tp, body[0]),
                    r.axis_if_divides(fsdp, body[1]))

    # ---- SSM / recurrent ------------------------------------------------------------
    if re.search(r"ssm/in_proj$", path) or re.search(r"rec/(in_x|in_y)$", path):
        return spec(r.axis_if_divides(fsdp, body[0]),
                    r.axis_if_divides(tp, body[1]))
    if re.search(r"ssm/out_proj$", path) or re.search(r"rec/out$", path):
        return spec(r.axis_if_divides(tp, body[0]),
                    r.axis_if_divides(fsdp, body[1]))
    if re.search(r"rec/gate_[ri]$", path):
        return spec(r.axis_if_divides(fsdp, body[0]),
                    r.axis_if_divides(tp, body[1]))
    if re.search(r"(ssm|rec)/conv_w$", path):
        return spec(None, r.axis_if_divides(tp, body[1]))

    # ---- fallback: shard the biggest dim on tp if divisible ----------------------------
    axes = [None] * nb
    order = sorted(range(nb), key=lambda i: -body[i])
    for i in order:
        a = r.axis_if_divides(tp, body[i])
        if a:
            axes[i] = a
            break
    return spec(*axes)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params: PyTree, rules: ShardingRules) -> PyTree:
    """PartitionSpec tree mirroring ``params`` (works on ShapeDtypeStructs)."""

    def assign(path, leaf):
        return _param_rule(_path_str(path), tuple(leaf.shape), rules)

    return jax.tree_util.tree_map_with_path(assign, params)


def opt_state_specs(opt_state: PyTree, params: PyTree, pspecs: PyTree,
                    rules: ShardingRules) -> PyTree:
    """Optimizer-state specs: moment tensors mirror their param's spec.

    Handles: adamw (m/v mirror params), adafactor (vr/vc take the matching
    prefix of the param spec), adamw8bit (q/scale blocked — replicate; the
    flattening breaks alignment with named dims), and scalar steps.
    """
    flat_p, _ = jax.tree.flatten(params)
    flat_s = jax.tree.leaves(pspecs,
                             is_leaf=lambda x: isinstance(x, P))
    shape_to_spec: Dict[Tuple[int, ...], P] = {}
    for p, s in zip(flat_p, flat_s):
        shape_to_spec.setdefault(tuple(p.shape), s)

    def assign(leaf):
        shp = tuple(leaf.shape)
        if shp in shape_to_spec:
            return shape_to_spec[shp]
        if len(shp) == 0:
            return P()
        # factored adafactor stats: match a param spec prefix/suffix
        for pshape, s in shape_to_spec.items():
            if shp == pshape[:-1]:
                return P(*tuple(s)[:-1]) if len(tuple(s)) >= len(shp) else P()
            if shp == pshape[:-2] + pshape[-1:]:
                t = tuple(s)
                if len(t) == len(pshape):
                    return P(*(t[:-2] + t[-1:]))
        return P()  # int8 blocks, scales, anything else: replicate

    return jax.tree.map(assign, opt_state)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def batch_specs(batch: PyTree, rules: ShardingRules) -> PyTree:
    """Inputs: batch dim over batch_axes; model-dim embeds optionally on tp."""

    def assign(path, leaf):
        b_axes = rules.batch_spec_axes(leaf.shape[0])
        rest = [None] * (len(leaf.shape) - 1)
        name = _path_str(path)
        if "frontend_embeds" in name and len(leaf.shape) == 3:
            rest[-1] = rules.axis_if_divides(rules.tp_axis, leaf.shape[-1])
        return P(b_axes, *rest)

    return jax.tree_util.tree_map_with_path(assign, batch)


def cache_specs(cache: PyTree, rules: ShardingRules, batch: int) -> PyTree:
    """Decode caches: batch over batch_axes; heads/width dims over tp."""
    b_axes = rules.batch_spec_axes(batch)

    def assign(path, leaf):
        shp = tuple(leaf.shape)
        name = _path_str(path)
        stacked = name.startswith("blocks/") or name.startswith("self/") \
            or name.startswith("cross/")
        lead = (None,) if stacked else ()
        body = shp[1:] if stacked else shp
        # KV cache (B, L, Hkv, dh): shard heads*... on tp if divisible
        axes = [None] * len(body)
        if len(body) >= 1:
            axes[0] = b_axes if body[0] == batch else None
        for i in range(len(body) - 1, 0, -1):
            a = rules.axis_if_divides(rules.tp_axis, body[i])
            if a:
                axes[i] = a
                break
        return P(*(lead + tuple(axes)))

    return jax.tree_util.tree_map_with_path(assign, cache)


def named(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def constrain(x, rules: ShardingRules, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


class ActivationSharding:
    """Constraint points the models call (via RuntimeConfig.act_sharding).

    Keeps GSPMD propagation on the rails: batch over the data axes, vocab
    (logits) over tp, and optionally the embed dim over tp ("2D activation
    sharding", a hillclimb lever).  No-op when unset (CPU tests).
    """

    def __init__(self, rules: ShardingRules):
        self.rules = rules

    def _spec(self, x, last_axis):
        b_axes = self.rules.batch_spec_axes(x.shape[0])
        mid = [None] * (x.ndim - 2)
        return P(b_axes, *mid, last_axis)

    def hidden(self, x):
        """(B, S, D) residual-stream activations.

        With ``seq_axis`` set (ZeRO-3 + sequence parallelism, used when
        global_batch < chips, e.g. the multi-pod mesh), the seq dim is
        sharded too: per-token ops run 1/seq_axis per device and attention
        consumes it via the "seq" shard mode.
        """
        r = self.rules
        tp = (r.axis_if_divides(r.tp_axis, x.shape[-1])
              if r.shard_activations_embed else None)
        if (r.seq_axis is not None and x.ndim == 3 and x.shape[1] > 1
                and x.shape[1] % r.size(r.seq_axis) == 0):
            b_axes = r.batch_spec_axes(x.shape[0])
            return constrain(x, r, P(b_axes, r.seq_axis, tp))
        return constrain(x, r, self._spec(x, tp))

    def logits(self, x):
        """(B, S, V_pad) — vocab over tp (Megatron layout: no gather)."""
        r = self.rules
        return constrain(
            x, r, self._spec(x, r.axis_if_divides(r.tp_axis, x.shape[-1])))

    def moe_expert_major(self, x):
        """(G, E, C, D/F) dispatched MoE activations: EXPERT-major layout
        (E over the expert axis).  The reshard from token-major (G over
        data) to expert-major lowers to an all-to-all — classic expert
        parallelism — instead of the replicate+all-reduce GSPMD otherwise
        invents for the expert einsums (measured 17 GiB all-reduces on
        arctic-480b).

        MEASURED RESULT (EXPERIMENTS.md §Perf, arctic iteration): GSPMD
        lowers this reshard to replicate+slice, NOT all-to-all — collective
        time got 3x WORSE, so it is OFF by default
        (rules.moe_expert_major).  The proper fix is a shard_map MoE with
        explicit lax.all_to_all (documented future work)."""
        r = self.rules
        if r.moe_layout == "grid":
            # GRID layout: token-groups over tp, experts over the expert
            # axis.  BOTH expert-einsum operands are sharded on FREE dims
            # (g on tp, e on data), so the big (G,E,C,*) einsums need NO
            # communication at all; only the cheap token-major <-> grid
            # reshards at the MoE boundary move data.
            ga = r.axis_if_divides(r.tp_axis, x.shape[0])
            ea = r.axis_if_divides(r.expert_axis, x.shape[1])
            return constrain(x, r, P(ga, ea, None, None))
        if r.moe_layout != "expert_major":
            return x
        ea = r.axis_if_divides(r.expert_axis, x.shape[1])
        return constrain(x, r, P(None, ea, None, None))

    def heads(self, x):
        """(B, S, H, dh) q/k/v.

        mode "heads": heads over tp when divisible, else explicitly
        REPLICATED (stops GSPMD from inventing pathological head_dim/padded
        shardings when H % tp != 0, e.g. qwen's 40 heads on 16).
        mode "seq": context parallelism — the SEQUENCE dim over tp; GSPMD
        all-gathers the (small, GQA) K/V while the S^2 score work stays
        1/tp per device regardless of head count."""
        r = self.rules
        b_axes = r.batch_spec_axes(x.shape[0])
        if r.attn_shard_mode == "seq" and x.shape[1] % max(
                r.size(r.tp_axis), 1) == 0 and x.shape[1] > 1:
            return constrain(x, r, P(b_axes, r.tp_axis, None, None))
        h_axis = r.axis_if_divides(r.tp_axis, x.shape[2])
        return constrain(x, r, P(b_axes, None, h_axis, None))
