"""Train / serve step builders with full sharding annotations.

``make_train_step`` returns a jit-able function
    (params, opt_state, batch) -> (params, opt_state, metrics)
with gradient-accumulation microbatching (lets GSPMD overlap the
reduce-scatter of one microbatch's grads with the next one's backward),
global-norm clipping, and the chosen optimizer.

``make_serve_steps`` returns (prefill_fn, decode_fn) for batched serving.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .optimizer import OptimizerConfig, clip_by_norm, make_optimizer

__all__ = ["TrainConfig", "make_train_step", "make_loss_fn",
           "make_serve_steps"]

PyTree = Any


@dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    microbatches: int = 1


def make_loss_fn(model):
    def loss_fn(params, batch):
        loss, aux = model.loss(params, batch)
        return loss, aux

    return loss_fn


def make_train_step(model, train_cfg: TrainConfig) -> Callable:
    opt = make_optimizer(train_cfg.optimizer)
    loss_fn = make_loss_fn(model)
    n_micro = train_cfg.microbatches

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(i):
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // n_micro),
                        x.shape[0] // n_micro, axis=0), batch)
                return jax.value_and_grad(loss_fn, has_aux=True)(params, mb)

            def body(carry, i):
                acc_g, acc_l = carry
                (l, _aux), g = micro(i)
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, g)
                return (acc_g, acc_l + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(n_micro))
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro
            aux = {"loss": loss}

        grads, gnorm = clip_by_norm(grads, train_cfg.optimizer.grad_clip)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": opt_state["step"]}
        return params, opt_state, metrics

    return train_step


def make_serve_steps(model):
    """(prefill, decode_step) for decoder LMs; enc-dec handled by the model's
    own signatures."""

    def prefill(params, tokens, frontend_embeds=None):
        return model.prefill(params, tokens, frontend_embeds)

    def decode(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return prefill, decode
