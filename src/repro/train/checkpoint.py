"""Checkpointing THROUGH the dataset platform (the paper's integration).

A checkpoint is a *dataset version*: each param/opt-state leaf is a record
(npy bytes + shape/dtype attrs) checked into the dataset manager.  That
buys, for free, exactly the platform features the paper lists: versioning
(step tags), access control, lineage (checkpoint PRODUCED_BY train run,
DERIVED_FROM the data snapshot it consumed), and revocation impact
("which checkpoints ingested record X").

Restore is **elastic**: arrays are laid out for whatever mesh/sharding the
*restoring* job passes in (``jax.device_put`` with the target
``NamedSharding``) — a checkpoint written on one topology restores onto
another, which is the checkpoint/restart + re-scale story for node failures.

Multi-host note: in a real multi-controller job each host writes only its
addressable shards (record-per-shard, attrs carry the index bounds) and
reads back its own; this container is single-process so records hold full
arrays, but the record schema already carries ``shard`` metadata.
"""

from __future__ import annotations

import io
import json
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import DatasetManager, Record
from ..core.lineage import EdgeKind, NodeKind

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "checkpoint_node_id"]

PyTree = Any


def _np_dtype(name: str):
    """Resolve dtype names incl. the ml_dtypes family (bfloat16, fp8...)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _leaf_records(tree: PyTree, prefix: str) -> List[Record]:
    # Raw bytes + (shape, dtype) attrs: np.save cannot round-trip bfloat16.
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    records = []
    for path, leaf in flat:
        name = prefix + "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        records.append(Record(name, arr.tobytes(), {
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "shard": "full",  # multi-host: "host{i}:{index bounds}"
        }))
    return records


def checkpoint_node_id(dataset: str, step: int) -> str:
    return f"checkpoint:{dataset}@step{step}"


def save_checkpoint(
    dm: DatasetManager,
    dataset: str,
    step: int,
    params: PyTree,
    opt_state: Optional[PyTree] = None,
    extra: Optional[Dict[str, Any]] = None,
    actor: str = "trainer",
    data_snapshot_id: Optional[str] = None,
    run_node: Optional[str] = None,
) -> str:
    """Returns the commit id of the checkpoint version."""
    records = _leaf_records(params, "params/")
    if opt_state is not None:
        records += _leaf_records(opt_state, "opt/")
    meta = {"step": step, "kind": "checkpoint"}
    if extra is not None:
        records.append(Record("extra.json", json.dumps(extra).encode(),
                              {"kind": "extra"}))
    commit = dm.check_in(
        dataset, records, actor=actor, message=f"checkpoint step {step}",
        version_tags=[f"step-{step}", "latest"], meta=meta,
        derived_from=[data_snapshot_id] if data_snapshot_id else [],
        produced_by=run_node,
    )
    node = checkpoint_node_id(dataset, step)
    dm.lineage.add_node(node, NodeKind.CHECKPOINT, dataset=dataset,
                        step=step, commit=commit.commit_id)
    from ..core.dataset import version_node_id
    dm.lineage.add_edge(node, version_node_id(dataset, commit.commit_id),
                        EdgeKind.DERIVED_FROM)
    if data_snapshot_id:
        dm.lineage.add_edge(node, data_snapshot_id, EdgeKind.DERIVED_FROM)
    dm.lineage.flush()
    return commit.commit_id


def _read_tree(snap, like: PyTree, prefix: str, shardings: Optional[PyTree],
               actor: str) -> PyTree:
    flat, tdef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (jax.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else [None] * len(flat))
    out = []
    for (path, leaf), shard in zip(flat, shard_flat):
        name = prefix + "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        attrs = snap.attrs(name)
        arr = np.frombuffer(snap.read(name),
                            dtype=_np_dtype(attrs["dtype"]))
        arr = arr.reshape(attrs["shape"])
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        if arr.dtype != want_dtype:
            arr = arr.astype(want_dtype)
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(tdef, [x for x in out])


def load_checkpoint(
    dm: DatasetManager,
    dataset: str,
    like_params: PyTree,
    like_opt: Optional[PyTree] = None,
    rev: str = "latest",
    param_shardings: Optional[PyTree] = None,
    opt_shardings: Optional[PyTree] = None,
    actor: str = "trainer",
) -> Tuple[PyTree, Optional[PyTree], Dict[str, Any]]:
    """Restore (params, opt_state, extra).  ``like_*`` give tree structure +
    dtypes (ShapeDtypeStructs fine); shardings lay arrays onto the TARGET
    mesh — pass the new mesh's shardings to re-scale elastically."""
    snap = dm.checkout(dataset, actor, rev=rev, register_snapshot=False)
    params = _read_tree(snap, like_params, "params/", param_shardings, actor)
    opt_state = None
    if like_opt is not None:
        opt_state = _read_tree(snap, like_opt, "opt/", opt_shardings, actor)
    extra: Dict[str, Any] = {}
    if any(rid == "extra.json" for rid in snap.iter_record_ids()):
        extra = json.loads(snap.read("extra.json").decode())
    return params, opt_state, extra


def latest_step(dm: DatasetManager, dataset: str) -> Optional[int]:
    tags = dm.versions.list_tags(dataset)
    steps = [int(t[5:]) for t in tags if t.startswith("step-")]
    return max(steps) if steps else None
