"""Config system: architecture + shape definitions for the assigned pool.

Every architecture in the assignment is a :class:`ModelConfig`; every
input-shape a :class:`ShapeConfig`.  A *cell* is (arch × shape); the dry-run
and roofline sweep iterate cells.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "Cell", "round_up"]


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # --- attention flavour -------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None    # SWA on EVERY attn layer (mixtral)
    local_window: Optional[int] = None      # window for "local" layers
    # Layer pattern within a repeating superblock, e.g.:
    #   ("attn",)                                  uniform dense
    #   ("local",)*5 + ("global",)                 gemma3 5:1
    #   ("local", "global")                        gemma2 alternating
    #   ("rec", "rec", "local")                    recurrentgemma 1:2
    #   ("ssm",)                                   mamba2
    pattern: Tuple[str, ...] = ("attn",)
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False            # arctic: dense FFN ∥ MoE
    capacity_factor: float = 1.25

    # --- SSM (mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # --- hybrid (RG-LRU) ------------------------------------------------------
    lru_width: Optional[int] = None

    # --- encoder-decoder -------------------------------------------------------
    n_encoder_layers: int = 0
    is_encoder_decoder: bool = False

    # --- modality frontend (STUB: precomputed embeddings via input_specs) ------
    frontend: Optional[str] = None          # "audio" | "vision"
    frontend_tokens: int = 0                # patches/frames occupying the prefix

    # --- misc ---------------------------------------------------------------------
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    act: str = "silu"                       # silu (SwiGLU) | gelu (GeGLU)
    post_norms: bool = False                # gemma2/3: extra post-sublayer norms
    scale_embed: bool = False               # gemma family: x *= sqrt(D)
    tie_embeddings: bool = False
    source: str = ""                        # provenance tag from the assignment

    # ------------------------------------------------------------------ derived

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so it always shards over 16-way axes."""
        return round_up(self.vocab_size, 256)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """True iff *no* layer attends to unbounded context (long_500k ok)."""
        if self.family == "ssm":
            return True
        kinds = set(self.pattern)
        if "global" in kinds or "attn" in kinds:
            # plain/global attention is unbounded unless SWA caps it
            return self.sliding_window is not None
        # only local/rec/ssm kinds left -> bounded windows
        return True

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Total parameter count (for 6ND model-flops accounting)."""
        V, D, F, L = self.padded_vocab, self.d_model, self.d_ff, self.n_layers
        Hq, Hkv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        emb = V * D * (1 if self.tie_embeddings else 2)
        total = emb
        per_layer: Dict[str, int] = {}
        attn = D * Hq * dh + 2 * D * Hkv * dh + Hq * dh * D
        mlp_dense = 3 * D * F if F else 0
        moe = self.n_experts * 3 * D * self.moe_d_ff if self.n_experts else 0
        router = D * self.n_experts if self.n_experts else 0
        ssm = 0
        if self.family == "ssm":
            Din, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            # in_proj -> (2*Din + 2*G*N + H), conv, out_proj, norm/dt
            ssm = D * (2 * Din + 2 * N + H) + Din * D + self.ssm_conv_width * (
                Din + 2 * N) + H
        rec = 0
        if "rec" in self.pattern:
            W = self.lru_width or D
            rec = 2 * D * W + W * D + 2 * W * self.ssm_conv_width + 4 * W

        n_rec = n_attn = n_ssm = 0
        pat = self.pattern
        for i in range(self.n_layers):
            k = pat[i % len(pat)]
            if k == "rec":
                n_rec += 1
            elif k == "ssm":
                n_ssm += 1
            else:
                n_attn += 1
        total += n_attn * attn + n_rec * rec + n_ssm * ssm
        if self.n_experts:
            total += self.n_layers * (moe + router)
            if self.dense_residual:
                total += self.n_layers * mlp_dense
        else:
            total += (n_attn + n_rec) * mlp_dense if self.family != "ssm" else 0
        if self.is_encoder_decoder:
            # encoder layers: self-attn + mlp; decoder already counted; add
            # cross-attention for decoder layers.
            total += self.n_encoder_layers * (attn + mlp_dense)
            total += self.n_layers * attn  # cross-attn per decoder layer
        return int(total)

    def n_active_params(self) -> int:
        """Active-per-token params (MoE: only routed experts count)."""
        if not self.n_experts:
            return self.n_params()
        full = self.n_params()
        all_experts = self.n_layers * self.n_experts * 3 * self.d_model * self.moe_d_ff
        active = self.n_layers * self.experts_per_token * 3 * self.d_model * self.moe_d_ff
        return int(full - all_experts + active)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    runnable: bool
    skip_reason: str = ""
