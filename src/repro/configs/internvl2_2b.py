"""internvl2-2b — InternViT (STUB) + InternLM2 language backbone.

[arXiv:2404.16821; hf] 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
(padded to 92672).  The vision frontend is a stub: ``input_specs()`` provides
precomputed patch embeddings occupying the sequence prefix.
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    rope_theta=1_000_000.0,
    pattern=("attn",),
    frontend="vision",
    frontend_tokens=1024,      # ViT patch embeddings occupying the prefix
    norm="rmsnorm",
    act="silu",
    source="arXiv:2404.16821; hf",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, frontend_tokens=8,
    )
