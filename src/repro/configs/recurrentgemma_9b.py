"""recurrentgemma-9b — Griffin-style hybrid: RG-LRU + local attention, 1:2.

[arXiv:2402.19427; unverified] 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000.  Pattern: (rec, rec, local) repeating; 38 = 12x3 + 2.
Sub-quadratic (recurrence + bounded window) -> long_500k runs.
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,              # MQA on the attention layers
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    local_window=2048,
    pattern=("rec", "rec", "local"),
    lru_width=4096,
    ssm_conv_width=4,
    norm="rmsnorm",
    act="gelu",
    tie_embeddings=True,
    scale_embed=True,
    source="arXiv:2402.19427; unverified",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, local_window=32, lru_width=64,
    )
