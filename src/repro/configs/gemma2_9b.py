"""gemma2-9b — dense GQA, alternating local/global attention, logit softcap.

[arXiv:2408.00118; hf] 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000.
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    rope_theta=10_000.0,
    local_window=4096,
    pattern=("local", "global"),
    attn_softcap=50.0,
    final_softcap=30.0,
    norm="rmsnorm",
    act="gelu",
    tie_embeddings=True,
    post_norms=True,
    scale_embed=True,
    source="arXiv:2408.00118; hf",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, local_window=32,
    )
