"""stablelm-1.6b — dense transformer, kv=32 (effectively MHA).

[hf:stabilityai/stablelm-2-1_6b; unverified] 24L d_model=2048 32H (GQA kv=32)
d_ff=5632 vocab=100352.
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    qkv_bias=False,
    rope_theta=10_000.0,
    pattern=("attn",),
    norm="layernorm",
    act="silu",
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
    )
