"""mamba2-1.3b — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060; unverified] 48L d_model=2048 (attn-free) vocab=50280
(padded to 50432), ssm_state=128.  O(1) state -> long_500k runs.
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    pattern=("ssm",),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    norm="rmsnorm",
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, vocab_size=512, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=16,
    )
