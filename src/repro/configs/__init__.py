"""Architecture registry: ``--arch <id>`` resolves here.

10 assigned architectures x 4 shapes = 40 cells.  ``cells()`` enumerates
them with runnability (long_500k needs sub-quadratic attention; the skip
rule is documented in DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from typing import Dict, List

from . import (arctic_480b, gemma2_9b, gemma3_12b, internvl2_2b, mamba2_1_3b,
               mixtral_8x22b, qwen2_5_32b, recurrentgemma_9b,
               seamless_m4t_medium, stablelm_1_6b)
from .base import SHAPES, Cell, ModelConfig, ShapeConfig

_MODULES = {
    "qwen2.5-32b": qwen2_5_32b,
    "stablelm-1.6b": stablelm_1_6b,
    "gemma3-12b": gemma3_12b,
    "gemma2-9b": gemma2_9b,
    "arctic-480b": arctic_480b,
    "mixtral-8x22b": mixtral_8x22b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "recurrentgemma-9b": recurrentgemma_9b,
    "mamba2-1.3b": mamba2_1_3b,
    "internvl2-2b": internvl2_2b,
}

ARCHS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    try:
        return _MODULES[arch].CONFIG
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}") from None


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].smoke_config()


def cell_runnable(arch: str, shape: str) -> Cell:
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return Cell(arch, shape, False,
                    "full-attention arch: 500k decode is quadratic "
                    "(global/full layers); skip per assignment rule")
    return Cell(arch, shape, True)


def cells() -> List[Cell]:
    return [cell_runnable(a, s) for a in ARCHS for s in SHAPES]


__all__ = ["ARCHS", "SHAPES", "Cell", "ModelConfig", "ShapeConfig",
           "get_config", "get_smoke_config", "cells", "cell_runnable"]
