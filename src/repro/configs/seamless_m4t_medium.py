"""seamless-m4t-medium — encoder-decoder multimodal (audio frontend STUB).

[arXiv:2308.11596; hf] 12L d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206.  The speech frontend is a stub: ``input_specs()`` provides
precomputed frame embeddings (B, S_enc, d_model).
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,               # decoder layers
    n_encoder_layers=12,
    is_encoder_decoder=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,         # padded to 256256 for sharding
    pattern=("attn",),
    frontend="audio",
    norm="layernorm",
    act="gelu",
    source="arXiv:2308.11596; hf",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
    )
