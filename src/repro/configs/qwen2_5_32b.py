"""qwen2.5-32b — dense GQA transformer with QKV bias.

[hf:Qwen/Qwen2.5-0.5B; hf] 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064.
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    pattern=("attn",),
    norm="rmsnorm",
    act="silu",
    source="hf:Qwen/Qwen2.5-0.5B; hf",
)


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
    )
