"""arctic-480b — 128-expert top-2 MoE with a dense residual MLP per layer.

[hf:Snowflake/snowflake-arctic-base; hf] 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000, MoE 128e top-2 + dense residual.
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,                 # the DENSE residual MLP width
    vocab_size=32000,
    rope_theta=10_000.0,
    pattern=("attn",),
    n_experts=128,
    experts_per_token=2,
    moe_d_ff=4864,
    dense_residual=True,
    norm="rmsnorm",
    act="silu",
    source="hf:Snowflake/snowflake-arctic-base; hf",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=512, n_experts=4, experts_per_token=2, moe_d_ff=96,
    )
