"""mixtral-8x22b — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf] 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, SWA.
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,                    # no dense MLP; MoE only
    vocab_size=32768,
    rope_theta=1_000_000.0,
    sliding_window=4096,       # SWA bounds the KV cache -> long_500k runs
    pattern=("attn",),
    n_experts=8,
    experts_per_token=2,
    moe_d_ff=16384,
    dense_residual=False,
    norm="rmsnorm",
    act="silu",
    source="arXiv:2401.04088; hf",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        vocab_size=512, n_experts=4, experts_per_token=2, moe_d_ff=128,
        sliding_window=32,
    )
