"""Platform CLI — the paper's "Users can use a command-line interface (CLI)
or other user interface to check-in data".

Every command opens the repository through :class:`repro.Platform`
(``Platform.open(repo_dir)``) and operates on dataset handles, so the CLI,
library callers, and workflows share one code path.  ``--where`` takes the
declarative query grammar of :func:`repro.core.query.parse_where` —
the same serializable algebra workflows use for their input queries, so a
query shown in a run report can be pasted back into the CLI verbatim.

A repository lives in a directory (FileBackend CAS).  Actors are passed via
``--actor`` (or $REPRO_ACTOR); ACL is enforced on every operation.

Examples:
    repro-cli --repo /tmp/repo check-in mydata file1.txt file2.bin -m "v1"
    repro-cli --repo /tmp/repo checkout mydata --out /tmp/restore
    repro-cli --repo /tmp/repo checkout mydata --where 'lang=en & split!=test'
    repro-cli --repo /tmp/repo checkout mydata --where 'size>=1024 | tags~=gold*'
    repro-cli --repo /tmp/repo derive mydata --pipeline clean \\
        --where 'lang=en' --output mydata-clean --pipelines-module my.pipes
    repro-cli --repo /tmp/repo tag mydata golden
    repro-cli --repo /tmp/repo datasets --tags text
    repro-cli --repo /tmp/repo log mydata
    repro-cli --repo /tmp/repo diff mydata <rev-a> <rev-b>
    repro-cli --repo /tmp/repo lineage <node-id>
    repro-cli --repo /tmp/repo revoke <record-id> --reason "user request"
    repro-cli --repo /tmp/repo grant alice 'speech/*' WRITE
    repro-cli --repo /tmp/repo cache ls
    repro-cli --repo /tmp/repo cache stats
    repro-cli --repo /tmp/repo cache prune --keep-latest 2
    repro-cli --repo /tmp/repo store stats
    repro-cli --repo 'http://localhost:8123' datasets

``--repo`` also accepts backend URLs (``memory://``, ``file:///path``,
``http://host:port`` — see :mod:`repro.store.remote.urls`), so the same
commands run against a remote object server; ``store stats`` then shows
the remote request / retry / hedge counters next to the cache tiers.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from .core import (NotFoundError, QueryParseError, Record, get_pipeline,
                   parse_where)
from .core.query import ALL
from .platform import Platform

__all__ = ["main"]


def _open(args) -> Platform:
    return Platform.open(args.repo, actor=args.actor)


def _at_least_one(text: str) -> int:
    value = int(text)
    if value < 1:
        raise argparse.ArgumentTypeError("must be >= 1")
    return value


def _parse_where_args(where_args: Optional[List[str]]):
    """AND together every repeated ``--where`` expression."""
    query = None
    for text in where_args or []:
        q = parse_where(text)
        query = q if query is None else query & q
    return query


def cmd_check_in(plat: Platform, args) -> int:
    records = []
    for path in args.files:
        with open(path, "rb") as f:
            data = f.read()
        records.append(Record(os.path.basename(path), data,
                              {"src_path": os.path.abspath(path)}))
    c = plat.dataset(args.dataset).check_in(
        records, message=args.message or "", version_tags=args.tag or [])
    print(f"checked in {len(records)} record(s) -> {c.commit_id}")
    return 0


def cmd_checkout(plat: Platform, args) -> int:
    plan = plat.dataset(args.dataset).plan(
        rev=args.rev, where=_parse_where_args(args.where), limit=args.limit)
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        # entries() caches the scan, so the snapshot() below reuses it
        for entry in plan.entries():
            with open(os.path.join(args.out, entry.record_id), "wb") as f:
                f.write(plat.store.get_blob(entry.blob))
        print(f"materialized {len(plan.entries())} record(s) to {args.out}")
        snap = plan.snapshot()
    else:
        snap = plan.snapshot()
        for entry in snap.entries():   # stream: no separate id list + lookup
            print(entry.record_id, json.dumps(dict(entry.attrs)))
    digest = plan.query_digest()
    print(f"snapshot {snap.snapshot_id} @ {snap.commit_id[:12]} "
          f"(query {digest[:12] if digest else 'opaque'})")
    return 0


def cmd_derive(plat: Platform, args) -> int:
    """Run a registered pipeline over a queried checkout — cached,
    incremental, streaming (the derivation engine)."""
    if args.pipelines_module:
        import importlib

        try:
            importlib.import_module(args.pipelines_module)
        except ImportError as e:
            raise NotFoundError(
                f"cannot import --pipelines-module "
                f"{args.pipelines_module!r}: {e}") from e
    pipeline = get_pipeline(args.pipeline)
    res = plat.dataset(args.dataset).derive(
        pipeline, output=args.output, rev=args.rev,
        where=_parse_where_args(args.where),
        use_cache=not args.no_cache, incremental=not args.no_cache,
        update_cache=not args.no_cache,
    )
    print(f"derivation {res.key or 'opaque (uncached)'}")
    if res.cache_hit:
        print(f"cache hit: {res.n_inputs} record(s), 0 executed")
    else:
        print(f"cache miss: {res.n_executed} executed, "
              f"{res.n_reused} reused of {res.n_inputs} record(s) "
              f"-> {res.n_outputs} output record(s)"
              + (" [incremental]" if res.incremental else ""))
    print(f"output commit {res.output_commit}")
    return 0


def cmd_datasets(plat: Platform, args) -> int:
    for ds in plat.datasets(args.glob, tags=args.tags or []):
        info = ds.info() or {}
        print(ds.name, json.dumps(info.get("tags", [])))
    return 0


def cmd_log(plat: Platform, args) -> int:
    for c in plat.dataset(args.dataset).log(rev=args.rev, limit=args.limit):
        print(f"{c.commit_id[:12]} {c.author:12s} {c.message}")
    return 0


def cmd_diff(plat: Platform, args) -> int:
    d = plat.dataset(args.dataset).diff(args.rev_a, args.rev_b)
    print(d.summary())
    for rid in d.added:
        print(f"A {rid}")
    for rid in d.removed:
        print(f"D {rid}")
    for rid in d.modified:
        print(f"M {rid}")
    return 0


def cmd_tag(plat: Platform, args) -> int:
    plat.dataset(args.dataset).tag_version(args.rev, args.tag)
    print(f"tagged {args.dataset}@{args.rev} as {args.tag}")
    return 0


def cmd_query(plat: Platform, args) -> int:
    """Inspect a --where expression: parsed JSON + stable fingerprint."""
    query = _parse_where_args(args.where) or ALL
    print(json.dumps(query.to_json(), indent=2))
    print(f"fingerprint {query.fingerprint()}")
    return 0


def cmd_lineage(plat: Platform, args) -> int:
    node = plat.lineage.node(args.node)
    if node is None:
        print(f"unknown node {args.node!r}", file=sys.stderr)
        return 1
    print("node:", json.dumps(node.to_json(), indent=2))
    print("ancestors:")
    for n in plat.ancestors(args.node):
        print("  <-", n)
    print("descendants:")
    for n in plat.descendants(args.node):
        print("  ->", n)
    return 0


def cmd_revoke(plat: Platform, args) -> int:
    report = plat.revoke(args.record, reason=args.reason or "")
    print(json.dumps(report.to_json(), indent=2))
    return 0


def cmd_grant(plat: Platform, args) -> int:
    plat.grant(args.subject, args.pattern, args.action)
    print(f"granted {args.action} on {args.pattern!r} to {args.subject}")
    return 0


def cmd_gc(plat: Platform, args) -> int:
    n = plat.gc()
    print(f"collected {n} unreachable object(s)")
    return 0


def _cache_slot_rows(plat: Platform):
    """(key, entry, prov size) rows of the derivation cache, newest first.

    The size comes from the slot's recorded ``prov_bytes`` — reading every
    prov blob just to len() it would make a listing cost O(total prov
    bytes); pre-PR-4 slots without the field show "-"."""
    rows = [(key, entry, entry.get("prov_bytes"))
            for key, entry in plat.derivations.cache.entries().items()]
    rows.sort(key=lambda r: r[1].get("created_at", 0.0), reverse=True)
    return rows


def cmd_cache(plat: Platform, args) -> int:
    """Inspect / prune the derivation cache (``cache ls`` / ``cache stats``
    / ``cache prune --keep-latest N``)."""
    cache = plat.derivations.cache
    if args.cache_cmd == "ls":
        rows = _cache_slot_rows(plat)
        if not rows:
            print("derivation cache is empty")
            return 0
        print("key,output_dataset,output_commit,n_inputs,n_outputs,"
              "prov_bytes,created_at")
        for key, entry, size in rows:
            created = entry.get("created_at")
            print(",".join(str(x) for x in (
                key,
                entry.get("output_dataset"),
                (entry.get("output_commit") or "")[:12],
                entry.get("n_inputs", 0),
                entry.get("n_outputs", 0),
                size if size is not None else "-",
                f"{created:.0f}" if created else "-")))
        return 0
    if args.cache_cmd == "stats":
        rows = _cache_slot_rows(plat)
        groups = {(e.get("query"), e.get("pipeline"),
                   e.get("output_dataset")) for _, e, _ in rows}
        prov_bytes = sum(size or 0 for _, _, size in rows)
        print(f"slots {len(rows)}")
        print(f"groups {len(groups)}  (distinct query+pipeline+output)")
        print(f"superseded {len(rows) - len(groups)}")
        print(f"prov_bytes {prov_bytes}")
        return 0
    if args.cache_cmd == "prune":
        removed = cache.prune(keep_latest=args.keep_latest)
        collected = plat.gc()
        print(f"pruned {len(removed)} superseded slot(s) "
              f"(kept latest {args.keep_latest} per group), "
              f"gc collected {collected} object(s)")
        return 0
    raise AssertionError(args.cache_cmd)  # pragma: no cover


def cmd_store(plat: Platform, args) -> int:
    """Storage-engine introspection (``store stats``)."""
    if args.store_cmd == "stats":
        print(json.dumps(plat.store_stats(), indent=2, sort_keys=True))
        return 0
    raise AssertionError(args.store_cmd)  # pragma: no cover


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="repro-cli",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--repo", required=True,
                    help="repository directory, or a backend URL "
                         "(memory://, file:///path, http://host:port)")
    ap.add_argument("--actor", default=os.environ.get("REPRO_ACTOR", "cli"))
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("check-in")
    p.add_argument("dataset")
    p.add_argument("files", nargs="+")
    p.add_argument("-m", "--message")
    p.add_argument("--tag", action="append")
    p.set_defaults(fn=cmd_check_in)

    p = sub.add_parser("checkout")
    p.add_argument("dataset")
    p.add_argument("--rev", default="main")
    p.add_argument("--out")
    p.add_argument("--where", action="append",
                   help="query expression, e.g. 'lang=en & split!=test' "
                        "(repeatable; repeats are ANDed). Bare values are "
                        "coerced to int/float/bool; quote to force a "
                        "string or to include spaces: \"k='some value'\"")
    p.add_argument("--limit", type=int)
    p.set_defaults(fn=cmd_checkout)

    p = sub.add_parser("query",
                       help="parse a --where expression and print its "
                            "JSON + fingerprint")
    p.add_argument("--where", action="append", required=True)
    p.set_defaults(fn=cmd_query)

    p = sub.add_parser("derive",
                       help="run a registered pipeline over a queried "
                            "checkout and check the result into --output "
                            "(cached on the derivation key)")
    p.add_argument("dataset")
    p.add_argument("--pipeline", required=True,
                   help="pipeline name registered via "
                        "repro.core.derive.register_pipeline")
    p.add_argument("--output", required=True,
                   help="dataset the derived version is checked into")
    p.add_argument("--rev", default="main")
    p.add_argument("--where", action="append",
                   help="same query algebra as checkout (repeats ANDed)")
    p.add_argument("--pipelines-module",
                   help="import this module first so it can register "
                        "pipelines")
    p.add_argument("--no-cache", action="store_true",
                   help="force a full recompute; do not read or write "
                        "the derivation cache")
    p.set_defaults(fn=cmd_derive)

    p = sub.add_parser("datasets")
    p.add_argument("--glob", default="*")
    p.add_argument("--tags", action="append")
    p.set_defaults(fn=cmd_datasets)

    p = sub.add_parser("log")
    p.add_argument("dataset")
    p.add_argument("--rev", default="main")
    p.add_argument("--limit", type=int, default=20)
    p.set_defaults(fn=cmd_log)

    p = sub.add_parser("diff")
    p.add_argument("dataset")
    p.add_argument("rev_a")
    p.add_argument("rev_b")
    p.set_defaults(fn=cmd_diff)

    p = sub.add_parser("tag")
    p.add_argument("dataset")
    p.add_argument("tag")
    p.add_argument("--rev", default="main")
    p.set_defaults(fn=cmd_tag)

    p = sub.add_parser("lineage")
    p.add_argument("node")
    p.set_defaults(fn=cmd_lineage)

    p = sub.add_parser("revoke")
    p.add_argument("record")
    p.add_argument("--reason")
    p.set_defaults(fn=cmd_revoke)

    p = sub.add_parser("grant")
    p.add_argument("subject")
    p.add_argument("pattern")
    p.add_argument("action", choices=["READ", "WRITE", "ADMIN"])
    p.set_defaults(fn=cmd_grant)

    p = sub.add_parser("gc")
    p.set_defaults(fn=cmd_gc)

    p = sub.add_parser("cache",
                       help="inspect or prune the derivation cache")
    cache_sub = p.add_subparsers(dest="cache_cmd", required=True)
    cache_sub.add_parser("ls", help="list cache slots, newest first")
    cache_sub.add_parser("stats", help="slot/group/provenance-size summary")
    cp = cache_sub.add_parser(
        "prune",
        help="drop superseded slots (older input commits of the same "
             "query+pipeline+output), then run gc")
    cp.add_argument("--keep-latest", type=_at_least_one, default=1,
                    metavar="N", help="slots to keep per group (default 1)")
    p.set_defaults(fn=cmd_cache)

    p = sub.add_parser("store",
                       help="storage-engine introspection")
    store_sub = p.add_subparsers(dest="store_cmd", required=True)
    store_sub.add_parser(
        "stats",
        help="read/write/cache/remote counters — incl. meta_requests/"
             "meta_batched/ref_cas_retries — + both cache tiers (JSON)")
    p.set_defaults(fn=cmd_store)

    args = ap.parse_args(argv)
    plat = _open(args)
    try:
        return args.fn(plat, args)
    except QueryParseError as e:
        print(f"error: bad --where expression: {e}", file=sys.stderr)
        return 2
    except NotFoundError as e:
        print(f"error: {e.args[0] if e.args else e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    sys.exit(main())
