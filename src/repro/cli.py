"""Platform CLI — the paper's "Users can use a command-line interface (CLI)
or other user interface to check-in data".

A repository lives in a directory (FileBackend CAS).  Actors are passed via
``--actor`` (or $REPRO_ACTOR); ACL is enforced on every operation.

Examples:
    repro-cli --repo /tmp/repo check-in mydata file1.txt file2.bin -m "v1"
    repro-cli --repo /tmp/repo checkout mydata --out /tmp/restore
    repro-cli --repo /tmp/repo tag mydata golden
    repro-cli --repo /tmp/repo datasets --tags text
    repro-cli --repo /tmp/repo log mydata
    repro-cli --repo /tmp/repo diff mydata <rev-a> <rev-b>
    repro-cli --repo /tmp/repo lineage <node-id>
    repro-cli --repo /tmp/repo revoke <record-id> --reason "user request"
    repro-cli --repo /tmp/repo grant alice 'speech/*' WRITE
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from .core import (AccessController, DatasetManager, FileBackend,
                   ObjectStore, Record, RevocationEngine)

__all__ = ["main"]


def _dm(repo: str) -> DatasetManager:
    store = ObjectStore(FileBackend(repo))
    return DatasetManager(store)


def cmd_check_in(dm, args) -> int:
    records = []
    for path in args.files:
        with open(path, "rb") as f:
            data = f.read()
        records.append(Record(os.path.basename(path), data,
                              {"src_path": os.path.abspath(path)}))
    c = dm.check_in(args.dataset, records, actor=args.actor,
                    message=args.message or "",
                    version_tags=args.tag or [])
    print(f"checked in {len(records)} record(s) -> {c.commit_id}")
    return 0


def cmd_checkout(dm, args) -> int:
    attrs = dict(kv.split("=", 1) for kv in (args.where or []))
    snap = dm.checkout(args.dataset, actor=args.actor, rev=args.rev,
                       attrs_equal=attrs or None, limit=args.limit)
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        for rid in snap.record_ids():
            with open(os.path.join(args.out, rid), "wb") as f:
                f.write(snap.read(rid))
        print(f"materialized {len(snap)} record(s) to {args.out}")
    else:
        for rid in snap.record_ids():
            print(rid, json.dumps(dict(snap.attrs(rid))))
    print(f"snapshot {snap.snapshot_id} @ {snap.commit_id[:12]}")
    return 0


def cmd_datasets(dm, args) -> int:
    for name in dm.query_datasets(args.glob, tags=args.tags or []):
        info = dm.dataset_info(name) or {}
        print(name, json.dumps(info.get("tags", [])))
    return 0


def cmd_log(dm, args) -> int:
    head = dm.versions.resolve(args.dataset, args.rev)
    for c in dm.versions.log(head, limit=args.limit):
        print(f"{c.commit_id[:12]} {c.author:12s} {c.message}")
    return 0


def cmd_diff(dm, args) -> int:
    d = dm.diff(args.dataset, args.rev_a, args.rev_b, actor=args.actor)
    print(d.summary())
    for rid in d.added:
        print(f"A {rid}")
    for rid in d.removed:
        print(f"D {rid}")
    for rid in d.modified:
        print(f"M {rid}")
    return 0


def cmd_tag(dm, args) -> int:
    dm.tag_version(args.dataset, args.rev, args.tag, actor=args.actor)
    print(f"tagged {args.dataset}@{args.rev} as {args.tag}")
    return 0


def cmd_lineage(dm, args) -> int:
    node = dm.lineage.node(args.node)
    if node is None:
        print(f"unknown node {args.node!r}", file=sys.stderr)
        return 1
    print("node:", json.dumps(node.to_json(), indent=2))
    print("ancestors:")
    for n in dm.lineage.ancestors(args.node):
        print("  <-", n)
    print("descendants:")
    for n in dm.lineage.descendants(args.node):
        print("  ->", n)
    return 0


def cmd_revoke(dm, args) -> int:
    report = RevocationEngine(dm).revoke(args.record, actor=args.actor,
                                         reason=args.reason or "")
    print(json.dumps(report.to_json(), indent=2))
    return 0


def cmd_grant(dm, args) -> int:
    dm.acl.grant(args.subject, args.pattern, args.action)
    print(f"granted {args.action} on {args.pattern!r} to {args.subject}")
    return 0


def cmd_gc(dm, args) -> int:
    n = dm.gc()
    print(f"collected {n} unreachable object(s)")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="repro-cli",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--repo", required=True, help="repository directory")
    ap.add_argument("--actor", default=os.environ.get("REPRO_ACTOR", "cli"))
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("check-in")
    p.add_argument("dataset")
    p.add_argument("files", nargs="+")
    p.add_argument("-m", "--message")
    p.add_argument("--tag", action="append")
    p.set_defaults(fn=cmd_check_in)

    p = sub.add_parser("checkout")
    p.add_argument("dataset")
    p.add_argument("--rev", default="main")
    p.add_argument("--out")
    p.add_argument("--where", action="append",
                   help="attr=value filter (repeatable)")
    p.add_argument("--limit", type=int)
    p.set_defaults(fn=cmd_checkout)

    p = sub.add_parser("datasets")
    p.add_argument("--glob", default="*")
    p.add_argument("--tags", action="append")
    p.set_defaults(fn=cmd_datasets)

    p = sub.add_parser("log")
    p.add_argument("dataset")
    p.add_argument("--rev", default="main")
    p.add_argument("--limit", type=int, default=20)
    p.set_defaults(fn=cmd_log)

    p = sub.add_parser("diff")
    p.add_argument("dataset")
    p.add_argument("rev_a")
    p.add_argument("rev_b")
    p.set_defaults(fn=cmd_diff)

    p = sub.add_parser("tag")
    p.add_argument("dataset")
    p.add_argument("tag")
    p.add_argument("--rev", default="main")
    p.set_defaults(fn=cmd_tag)

    p = sub.add_parser("lineage")
    p.add_argument("node")
    p.set_defaults(fn=cmd_lineage)

    p = sub.add_parser("revoke")
    p.add_argument("record")
    p.add_argument("--reason")
    p.set_defaults(fn=cmd_revoke)

    p = sub.add_parser("grant")
    p.add_argument("subject")
    p.add_argument("pattern")
    p.add_argument("action", choices=["READ", "WRITE", "ADMIN"])
    p.set_defaults(fn=cmd_grant)

    p = sub.add_parser("gc")
    p.set_defaults(fn=cmd_gc)

    args = ap.parse_args(argv)
    dm = _dm(args.repo)
    return args.fn(dm, args)


if __name__ == "__main__":
    sys.exit(main())
