"""Production mesh construction.

Single pod: 256 TPU v5e chips as (16, 16) over ("data", "model").
Multi-pod: 2 pods = 512 chips as (2, 16, 16) over ("pod", "data", "model")
— the "pod" axis maps to DCN; pure data parallelism crosses it.

A FUNCTION (not a module constant) so importing this module never touches
jax device state; the dry-run sets XLA_FLAGS before first jax init.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def _auto_kwargs(n):
    # jax >= 0.5 wants explicit AxisType.Auto; older versions predate the
    # concept (Auto is the only behavior) and reject the kwarg.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_kwargs(len(axes)))


def make_local_mesh():
    """Whatever devices exist, as a 1-D data mesh (CPU tests, examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",), **_auto_kwargs(1))
