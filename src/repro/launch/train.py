"""End-to-end training driver: the paper's platform feeding a JAX trainer.

Flow (exactly Fig. 1 of the disclosure):
  1. raw text is checked into the dataset manager (pipeline A),
  2. a registered workflow (tokenize -> pack) produces the training
     snapshot (pipeline X),
  3. the trainer checks the snapshot out, trains with pjit on a mesh,
  4. checkpoints are checked back in as dataset versions with lineage
     (snapshot -> train run -> checkpoint), so revoking a raw record
     reports the checkpoints that transitively ingested it.

Fault tolerance: training resumes exactly from (checkpoint, loader state);
``--kill-at`` demonstrates a mid-run crash + restart recovering bit-exact.

This driver runs a REDUCED config on local devices (CPU here); the
production meshes are exercised by dryrun.py (same code path, bigger mesh).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch mamba2-1.3b \
        --steps 50 --smoke
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core import Pipeline, Record, Workflow
from ..core.lineage import NodeKind
from ..platform import Platform
from ..data import (DeviceFeed, PackComponent, ShardedSnapshotLoader,
                    SplitComponent, TokenizeComponent)
from ..models import RuntimeConfig, build_model
from ..train import (TrainConfig, load_checkpoint, make_train_step,
                     save_checkpoint)
from ..train.optimizer import OptimizerConfig, make_optimizer
from ..train.sharding import (ActivationSharding, ShardingRules, batch_specs,
                              named, opt_state_specs, param_specs)
from .mesh import make_local_mesh


def synthetic_corpus(n_docs: int = 256, seed: int = 0):
    """Deterministic synthetic text corpus (no network in this container)."""
    rng = np.random.default_rng(seed)
    words = [f"w{i:03d}" for i in range(100)]
    docs = []
    for i in range(n_docs):
        n = int(rng.integers(20, 200))
        text = " ".join(rng.choice(words, size=n))
        docs.append(Record(f"doc-{i:05d}", text.encode(), {"lang": "en"}))
    return docs


def build_platform(seq_len: int, n_docs: int = 256):
    """Stand up the platform and run the Fig. 1 pipelines."""
    plat = Platform.open(actor="trainer")
    plat.dataset("corpus/raw").check_in(
        synthetic_corpus(n_docs), actor="ingest",
        message="pipeline A: ingest")
    plat.register(Workflow(
        name="tokenize-pack",
        pipeline=Pipeline([SplitComponent(eval_fraction=0.0),
                           TokenizeComponent(),
                           PackComponent(seq_len=seq_len)], name="tok-pack"),
        input_dataset="corpus/raw",
        output_dataset="corpus/packed",
        n_shards=2,
    ))
    run = plat.run("tokenize-pack")
    assert run.state == "SUCCEEDED", run.error
    return plat, run


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--kill-at", type=int, default=None,
                    help="simulate a crash after N steps, then restart "
                         "from the platform checkpoint")
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--shuffle", default="auto",
                    choices=["auto", "global", "page_window"],
                    help="loader shuffle mode (auto: page-window streaming "
                         "above the size threshold, else legacy global)")
    ap.add_argument("--window-pages", type=int, default=8,
                    help="page-window shuffle width (pages per window)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_local_mesh()
    rules = ShardingRules(mesh, batch_axes=("data",), fsdp_axis=None,
                          tp_axis=None)
    rt = RuntimeConfig(compute_dtype=jnp.float32, attn_impl="naive",
                       ssd_impl="xla", rglru_impl="xla",
                       act_sharding=ActivationSharding(rules))
    model = build_model(cfg, rt)

    plat, wf_run = build_platform(args.seq_len, n_docs=max(
        args.batch * 8, 128))
    dm = plat.manager
    snap = plat.dataset("corpus/packed").checkout()
    print(f"platform: snapshot {snap.snapshot_id} with {len(snap)} packs")

    # The loader feeds from the lazy plan (page-granular read surface; the
    # registered snapshot above carries lineage) — page-window streaming
    # never materializes the manifest, global mode is the legacy baseline.
    loader = ShardedSnapshotLoader(
        plat.dataset("corpus/packed").plan(), args.batch, args.seq_len,
        shuffle=args.shuffle, window_pages=args.window_pages)
    train_cfg = TrainConfig(optimizer=OptimizerConfig(
        name="adamw", lr=args.lr, warmup_steps=10, total_steps=args.steps))
    opt = make_optimizer(train_cfg.optimizer)
    step_fn = jax.jit(make_train_step(model, train_cfg),
                      donate_argnums=(0, 1))

    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    run_node = f"train_run:{int(time.time())}"
    dm.lineage.add_node(run_node, NodeKind.WORKFLOW_RUN, kind_detail="train",
                        arch=cfg.name)
    dm.lineage.add_edge(snap.snapshot_id, run_node, "input_to")
    dm.lineage.flush()

    losses = []
    step = 0

    from jax.sharding import NamedSharding

    def batch_shardings(host_batch):
        return {k: NamedSharding(mesh, s)
                for k, s in batch_specs(host_batch, rules).items()}

    def do_train(until: int):
        """Drive the step loop from the double-buffered device feed: the
        next batch's host decode AND device transfer overlap the current
        train_step, and each yielded batch carries the loader state that
        makes its checkpoint bit-exact to resume."""
        nonlocal params, opt_state, step
        if step >= until:
            return
        feed_it = iter(DeviceFeed(loader, sharding_fn=batch_shardings))
        try:
            while step < until:
                batch, loader_state = next(feed_it)
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                step += 1
                losses.append(float(metrics["loss"]))
                if step % args.log_every == 0 or step == until:
                    print(f"step {step:5d} loss {losses[-1]:.4f}")
                if step % args.checkpoint_every == 0:
                    cid = save_checkpoint(
                        dm, f"checkpoints/{cfg.name}", step, params, opt_state,
                        extra={"loader": loader_state},
                        data_snapshot_id=snap.snapshot_id, run_node=run_node)
                    print(f"  checkpointed step {step} -> version {cid[:12]}")
        finally:
            feed_it.close()   # stop decode workers; buffered batches drop

    if args.kill_at and args.kill_at < args.steps:
        do_train(args.kill_at)
        print(f"--- simulated crash at step {step}; restarting ---")
        # Restart path: fresh process state, restore from the platform.
        like_p = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        like_o = jax.eval_shape(opt.init, like_p)
        params, opt_state, extra = load_checkpoint(
            dm, f"checkpoints/{cfg.name}", like_p, like_o)
        loader.restore(extra["loader"])
        step = int(np.asarray(opt_state["step"]))
        print(f"restored at step {step}, loader {extra['loader']}")

    do_train(args.steps)

    cid = save_checkpoint(dm, f"checkpoints/{cfg.name}", step, params,
                          opt_state, extra={"loader": loader.state()},
                          data_snapshot_id=snap.snapshot_id,
                          run_node=run_node)
    print(f"final checkpoint -> {cid[:12]}")
    ld_stats = loader.stats()
    print(f"loader: mode={ld_stats['mode']} "
          f"wait_fraction={ld_stats['wait_fraction']:.3f} "
          f"pages_streamed={int(ld_stats['pages_streamed'])} "
          f"peak_resident_ids={int(ld_stats['peak_resident_ids'])}")
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"loss: first5={first:.4f} last5={last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    # lineage: the checkpoint's provenance reaches the raw corpus
    from ..train.checkpoint import checkpoint_node_id

    anc = dm.lineage.ancestors(checkpoint_node_id(f"checkpoints/{cfg.name}",
                                                  step))
    print(f"lineage ancestors of final checkpoint: {len(anc)} node(s)")
    return {"losses": losses, "steps": step, "dm": dm, "platform": plat,
            "checkpoint": cid, "improved": bool(last < first),
            "loader": loader, "loader_stats": ld_stats}


if __name__ == "__main__":
    main()
