"""Batched serving driver: checkout a model checkpoint from the platform,
prefill a batch of prompts, decode tokens.

Demonstrates the serving side of the reproduction: the checkpoint is a
*dataset version* (ACL-checked on checkout, lineage-tracked), prefill
builds the KV/state caches, and decode steps are jitted with donated
caches.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
        --smoke --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..models import RuntimeConfig, build_model


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rt = RuntimeConfig(compute_dtype=jnp.float32, attn_impl="naive",
                       ssd_impl="xla", rglru_impl="xla",
                       max_cache_len=args.prompt_len + args.gen)
    model = build_model(cfg, rt)
    params = model.init(jax.random.PRNGKey(0))

    B = args.batch
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (B, args.prompt_len), 3,
                                 cfg.vocab_size)

    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.time()
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(key, (B, args.prompt_len, cfg.d_model),
                                   jnp.float32) * 0.1
        logits, cache, pos = model.prefill(params, frames,
                                           prompts[:, :1])
    else:
        logits, cache, pos = model.prefill(params, prompts)
    prefill_s = time.time() - t0

    generated = []
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    t1 = time.time()
    for i in range(args.gen):
        generated.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(pos + i, jnp.int32))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1, :] / args.temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None] \
                .astype(jnp.int32)
    jax.block_until_ready(tok)
    decode_s = time.time() - t1

    toks = np.concatenate(generated, axis=1)
    tput = B * args.gen / max(decode_s, 1e-9)
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {prefill_s*1e3:.1f} ms   decode: {decode_s*1e3:.1f} ms "
          f"({tput:.1f} tok/s incl. first-call compile)")
    print("sample token ids:", toks[0][:12].tolist())
    return {"tokens": toks, "prefill_s": prefill_s, "decode_s": decode_s,
            "tok_per_s": tput}


if __name__ == "__main__":
    main()
