"""Compiled-HLO analysis: collective inventory + roofline terms.

``cost_analysis()`` gives per-device HLO FLOPs and bytes; collective traffic
is NOT in there, so we parse the post-SPMD compiled HLO text and sum the
bytes each collective moves per device:

    all-gather:          result_bytes * (n-1)/n      (data received)
    all-reduce:          2 * in_bytes * (n-1)/n      (ring: RS + AG phases)
    reduce-scatter:      in_bytes * (n-1)/n
    all-to-all:          result_bytes * (n-1)/n
    collective-permute:  result_bytes

where n = participants per replica group (parsed from ``replica_groups``).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["HW", "CollectiveStats", "parse_collectives", "roofline_terms"]


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per link (per direction)


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9]+\[[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shapes_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    total_wire_bytes: float = 0.0       # per-device bytes on the wire
    lines: List[str] = field(default_factory=list)

    def to_json(self) -> dict:
        return {"counts": self.counts, "bytes_by_kind": self.bytes_by_kind,
                "total_wire_bytes": self.total_wire_bytes}


def parse_collectives(hlo_text: str, keep_lines: int = 0) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async pairs: count the -start only
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes_str, kind = m.group(1), m.group(2)
        result_bytes = _shape_bytes(shapes_str)
        n = _group_size(line)
        frac = (n - 1) / max(n, 1)
        if kind == "all-reduce":
            wire = 2.0 * result_bytes * frac
        elif kind == "reduce-scatter":
            wire = result_bytes * (n - 1)   # input = result * n
        elif kind == "all-gather":
            wire = result_bytes * frac
        elif kind == "all-to-all":
            wire = result_bytes * frac
        else:                               # collective-permute
            wire = float(result_bytes)
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0.0) + wire
        stats.total_wire_bytes += wire
        if keep_lines and len(stats.lines) < keep_lines:
            stats.lines.append(line.strip()[:200])
    return stats


def roofline_terms(
    hlo_flops: float,
    hlo_bytes: float,
    wire_bytes: float,
    hw: HW = HW(),
    n_links: int = 4,
) -> Dict[str, float]:
    """Three per-device roofline terms in seconds.

    ``hlo_flops``/``hlo_bytes`` come from cost_analysis() (already
    per-device after SPMD partitioning); ``wire_bytes`` from
    :func:`parse_collectives`.  ``n_links`` ~ ICI links per chip on a v5e
    torus (4: +x, -x, +y, -y usable concurrently for ring collectives).
    """
    compute_s = hlo_flops / hw.peak_flops
    memory_s = hlo_bytes / hw.hbm_bw
    collective_s = wire_bytes / (hw.ici_bw * n_links)
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": max(compute_s, memory_s, collective_s),
    }
