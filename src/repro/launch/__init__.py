# Launch layer: production mesh, multi-pod dry-run, train/serve drivers.
# NOTE: do NOT import dryrun here — it sets XLA_FLAGS at import time and
# must stay an explicit entry point.
from .mesh import make_local_mesh, make_production_mesh

__all__ = ["make_local_mesh", "make_production_mesh"]
