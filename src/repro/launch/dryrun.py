import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST precede every other import (jax locks the
# device count at first init), which is why the module docstring follows.

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step, in_shardings=..., out_shardings=...)
.lower(**ShapeDtypeStructs).compile()`` must succeed on the production
meshes (16x16 single-pod; 2x16x16 multi-pod), and the compiled artifact
yields ``memory_analysis()`` (fits?) + ``cost_analysis()`` (FLOPs/bytes)
plus the collective inventory for §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch qwen2.5-32b --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all \
        --out artifacts/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES, cell_runnable, get_config
from ..models import build_model
from ..train.sharding import (ActivationSharding, ShardingRules, batch_specs,
                              cache_specs, named, opt_state_specs,
                              param_specs)
from ..train.step import make_train_step
from .hlo_analysis import HW, parse_collectives, roofline_terms
from .mesh import make_production_mesh
from .specs import (input_specs, runtime_for, serve_token_specs,
                    train_config_for)

SDS = jax.ShapeDtypeStruct


def _mesh_tag(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)


def _data_parallel(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def build_train_lowering(cfg, shape, mesh, rules, rt_overrides=None,
                         tc_overrides=None):
    rt = runtime_for(cfg, shape, act_sharding=ActivationSharding(rules),
                     **(rt_overrides or {}))
    model = build_model(cfg, rt)
    params_abs = model.init_abstract()
    pspecs = param_specs(params_abs, rules)
    tc = train_config_for(cfg, shape, _data_parallel(mesh))
    if tc_overrides:
        tc = dataclasses.replace(tc, **tc_overrides)
    from ..train.optimizer import make_optimizer

    opt = make_optimizer(tc.optimizer)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    ospecs = opt_state_specs(opt_abs, params_abs, pspecs, rules)
    batch_abs = input_specs(cfg, shape)
    bspecs = batch_specs(batch_abs, rules)

    step = make_train_step(model, tc)
    jitted = jax.jit(
        step,
        in_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                      named(mesh, bspecs)),
        out_shardings=(named(mesh, pspecs), named(mesh, ospecs), None),
        donate_argnums=(0, 1),
    )
    lowered = jitted.lower(params_abs, opt_abs, batch_abs)
    return lowered, {"microbatches": tc.microbatches,
                     "optimizer": tc.optimizer.name,
                     "step_kind": "train_step"}


def build_prefill_lowering(cfg, shape, mesh, rules, rt_overrides=None):
    rt = runtime_for(cfg, shape, max_cache_len=shape.seq_len,
                     act_sharding=ActivationSharding(rules),
                     **(rt_overrides or {}))
    model = build_model(cfg, rt)
    params_abs = model.init_abstract()
    pspecs = param_specs(params_abs, rules)
    B, S = shape.global_batch, shape.seq_len
    b_axes = rules.batch_spec_axes(B)
    from jax.sharding import PartitionSpec as P

    if cfg.is_encoder_decoder:
        frames = SDS((B, S, cfg.d_model), jnp.bfloat16)
        tokens = SDS((B, S), jnp.int32)

        def fn(params, frames, tokens):
            return model.prefill(params, frames, tokens)

        in_sh = (named(mesh, pspecs),
                 named(mesh, P(b_axes, None, None)),
                 named(mesh, P(b_axes, None)))
        lowered = jax.jit(fn, in_shardings=in_sh).lower(
            params_abs, frames, tokens)
    elif cfg.frontend == "vision":
        Pf = cfg.frontend_tokens
        tokens = SDS((B, S - Pf), jnp.int32)
        fe = SDS((B, Pf, cfg.d_model), jnp.bfloat16)

        def fn(params, tokens, fe):
            return model.prefill(params, tokens, fe)

        in_sh = (named(mesh, pspecs), named(mesh, P(b_axes, None)),
                 named(mesh, P(b_axes, None, None)))
        lowered = jax.jit(fn, in_shardings=in_sh).lower(
            params_abs, tokens, fe)
    else:
        tokens = SDS((B, S), jnp.int32)

        def fn(params, tokens):
            return model.prefill(params, tokens, None)

        in_sh = (named(mesh, pspecs), named(mesh, P(b_axes, None)))
        lowered = jax.jit(fn, in_shardings=in_sh).lower(params_abs, tokens)
    return lowered, {"step_kind": "prefill"}


def build_decode_lowering(cfg, shape, mesh, rules, rt_overrides=None):
    rt = runtime_for(cfg, shape, max_cache_len=shape.seq_len,
                     act_sharding=ActivationSharding(rules),
                     **(rt_overrides or {}))
    model = build_model(cfg, rt)
    params_abs = model.init_abstract()
    pspecs = param_specs(params_abs, rules)
    B, S = shape.global_batch, shape.seq_len
    token_abs, pos_abs = serve_token_specs(cfg, shape)
    from jax.sharding import PartitionSpec as P

    if cfg.is_encoder_decoder:
        from ..models.attention import init_kv_cache

        enc_abs = SDS((B, S, cfg.d_model), jnp.bfloat16)
        cache_abs = jax.eval_shape(
            lambda p, e: {
                "self": jax.tree.map(
                    lambda *xs: jnp.stack(xs),
                    *[init_kv_cache(cfg, B, rt.max_cache_len,
                                    rt.compute_dtype)
                      for _ in range(cfg.n_layers)]),
                "cross": model._cross_kv(p["decoder"], e),
            }, params_abs, enc_abs)
    else:
        cache_abs = jax.eval_shape(lambda: model.init_cache(B))
    cspecs = cache_specs(cache_abs, rules, B)
    b_axes = rules.batch_spec_axes(B)

    def fn(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    jitted = jax.jit(
        fn,
        in_shardings=(named(mesh, pspecs), named(mesh, cspecs),
                      named(mesh, P(b_axes, None)), None),
        out_shardings=(None, named(mesh, cspecs)),
        donate_argnums=(1,),
    )
    lowered = jitted.lower(params_abs, cache_abs, token_abs, pos_abs)
    return lowered, {"step_kind": "decode_step"}


def run_cell(arch: str, shape_name: str, mesh, rules=None,
             rt_overrides=None, tc_overrides=None,
             hw: HW = HW()) -> Dict[str, Any]:
    """Lower + compile one cell; return the §Dry-run / §Roofline record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cell = cell_runnable(arch, shape_name)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": _mesh_tag(mesh),
        "runnable": cell.runnable, "skip_reason": cell.skip_reason,
    }
    if not cell.runnable:
        rec["status"] = "skipped"
        return rec
    rules = rules or ShardingRules(mesh)
    t0 = time.time()
    try:
        if shape.kind == "train":
            lowered, meta = build_train_lowering(
                cfg, shape, mesh, rules, rt_overrides, tc_overrides)
        elif shape.kind == "prefill":
            lowered, meta = build_prefill_lowering(
                cfg, shape, mesh, rules, rt_overrides)
        else:
            lowered, meta = build_decode_lowering(
                cfg, shape, mesh, rules, rt_overrides)
        rec.update(meta)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        ca = compiled.cost_analysis() or {}
        rec["hlo_flops"] = float(ca.get("flops", 0.0))
        rec["hlo_bytes"] = float(ca.get("bytes accessed", 0.0))
        ma = compiled.memory_analysis()
        if ma is not None:
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "peak_estimate_bytes": int(
                    ma.argument_size_in_bytes + ma.output_size_in_bytes
                    + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
            }
        colls = parse_collectives(compiled.as_text())
        rec["collectives"] = colls.to_json()
        rec["roofline"] = roofline_terms(
            rec["hlo_flops"], rec["hlo_bytes"], colls.total_wire_bytes, hw)
        n_dev = mesh.devices.size
        _add_model_terms(rec, cfg, shape, n_dev, hw)
        model_flops = model_flops_for(cfg, shape)
        rec["model_flops_global"] = model_flops
        rec["model_flops_per_device"] = model_flops / n_dev
        if rec["hlo_flops"] > 0:
            rec["useful_flops_ratio"] = (
                rec["model_flops_per_device"] / rec["hlo_flops"])
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 - record, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=8)
    return rec


def _reduced_cfg(cfg, n_superblocks: int):
    """cfg with n_superblocks repeats of the layer pattern (no tail)."""
    k = len(cfg.pattern)
    kw = {"n_layers": k * n_superblocks}
    if cfg.is_encoder_decoder:
        kw["n_encoder_layers"] = n_superblocks
        kw["n_layers"] = n_superblocks
    return dataclasses.replace(cfg, **kw)


def run_cell_roofline(arch: str, shape_name: str, mesh, rules=None,
                      rt_overrides=None, hw: HW = HW()) -> Dict[str, Any]:
    """Accurate roofline terms via 2-point layer extrapolation.

    ``cost_analysis()`` counts a ``lax.scan`` body ONCE regardless of trip
    count, so scanned lowerings under-report per-step FLOPs/bytes/collective
    traffic.  Instead we lower UNROLLED graphs with 1 and 2 superblocks
    (microbatches=1), take the difference as the exact per-superblock cost,
    and extrapolate linearly to the full depth:

        est(X) = X(1) + (X(2) - X(1)) * (n_layers/k - 1)

    The non-layer part (embed, logits, loss, optimizer) is captured at full
    size in the 1-superblock lowering.  Linear-in-depth holds exactly for
    transformer stacks (every superblock does identical work).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cell = cell_runnable(arch, shape_name)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": _mesh_tag(mesh),
        "runnable": cell.runnable, "skip_reason": cell.skip_reason,
        "method": "2-point layer extrapolation (unrolled, micro=1)",
    }
    if not cell.runnable:
        rec["status"] = "skipped"
        return rec
    rules = rules or ShardingRules(mesh)
    rt_o = dict(rt_overrides or {})
    rt_o["scan_layers"] = False
    # Single-block flash: the chunked XLA path hides its inner kv/q loops in
    # lax.scan bodies that cost_analysis counts ONCE; one big block makes the
    # attention HLO explicit so its FLOPs/bytes are counted exactly.  (For
    # windowed layers this over-counts vs a block-skipping kernel — the
    # analytic MODEL_FLOPS column uses the true window; see EXPERIMENTS.md.)
    rt_o.setdefault("attn_block_q", shape.seq_len)
    rt_o.setdefault("attn_block_k", shape.seq_len)
    k = len(cfg.pattern)
    reps = cfg.n_layers / k if not cfg.is_encoder_decoder else cfg.n_layers
    try:
        points = []
        for n_sb in (1, 2):
            sub = _reduced_cfg(cfg, n_sb)
            if shape.kind == "train":
                lowered, _ = build_train_lowering(
                    sub, shape, mesh, rules, rt_o,
                    tc_overrides={"microbatches": 1})
            elif shape.kind == "prefill":
                lowered, _ = build_prefill_lowering(
                    sub, shape, mesh, rules, rt_o)
            else:
                lowered, _ = build_decode_lowering(
                    sub, shape, mesh, rules, rt_o)
            t0 = time.time()
            compiled = lowered.compile()
            ca = compiled.cost_analysis() or {}
            colls = parse_collectives(compiled.as_text())
            points.append({
                "flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0)),
                "wire": colls.total_wire_bytes,
                "coll_counts": colls.counts,
                "compile_s": round(time.time() - t0, 2),
            })
        p1, p2 = points

        def extrap(key):
            return p1[key] + (p2[key] - p1[key]) * (reps - 1)

        rec["per_superblock"] = {
            "flops": p2["flops"] - p1["flops"],
            "bytes": p2["bytes"] - p1["bytes"],
            "wire": p2["wire"] - p1["wire"],
        }
        rec["points"] = points
        rec["hlo_flops"] = extrap("flops")
        rec["hlo_bytes"] = extrap("bytes")
        rec["wire_bytes"] = extrap("wire")
        rec["roofline"] = roofline_terms(
            rec["hlo_flops"], rec["hlo_bytes"], rec["wire_bytes"], hw)
        n_dev = mesh.devices.size
        _add_model_terms(rec, cfg, shape, n_dev, hw)
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=8)
    return rec


def _add_model_terms(rec, cfg, shape, n_dev, hw):
    """Model-side accounting: useful flops, analytic memory bound, and
    roofline fractions against both the HLO and analytic bounds."""
    model_flops = model_flops_for(cfg, shape)
    rec["model_flops_global"] = model_flops
    rec["model_flops_per_device"] = model_flops / n_dev
    mem_model = model_memory_bytes(cfg, shape, n_dev)
    r = rec["roofline"]
    r["memory_model_s"] = mem_model / hw.hbm_bw
    r["bound_model_s"] = max(r["compute_s"], r["memory_model_s"],
                             r["collective_s"])
    r["dominant_model"] = max(
        ("compute", r["compute_s"]), ("memory", r["memory_model_s"]),
        ("collective", r["collective_s"]), key=lambda kv: kv[1])[0]
    if rec["hlo_flops"] > 0:
        rec["useful_flops_ratio"] = (
            rec["model_flops_per_device"] / rec["hlo_flops"])
        ideal_s = rec["model_flops_per_device"] / hw.peak_flops
        rec["roofline_fraction"] = ideal_s / max(r["bound_s"], 1e-12)
        rec["roofline_fraction_model"] = ideal_s / max(
            r["bound_model_s"], 1e-12)


def model_memory_bytes(cfg, shape, n_dev: int) -> float:
    """Analytic per-device HBM traffic (bytes/step) — the fusion-ideal
    LOWER bound companion to the HLO ``bytes accessed`` UPPER bound (the
    CPU backend fuses less than TPU, inflating the HLO number).

    Inventory (documented in EXPERIMENTS.md §Roofline):
    - weights: fully sharded; train reads them 3x (fwd, remat fwd, bwd) +
      grad write/read + optimizer state read/write; prefill/decode 1x.
    - activations: residual stream + mlp/attn intermediates,
      ~(8*d_model + 3*d_ff_eff + heads) per token per layer, x4 train
      (fwd+remat+bwd write/read), x1.5 inference.
    - logits: tokens x padded_vocab x 4B x 3 / tp (sharded over tp=16).
    - decode adds the KV/state cache read+write.
    """
    pb = 2 if cfg.n_params() > 5e9 else 4
    P_tot, P_act = cfg.n_params(), cfg.n_active_params()
    B, S = shape.global_batch, shape.seq_len
    tp = 16
    opt_b = 6 if P_tot > 1e11 else 20
    if shape.kind == "train":
        tokens_loc = B * S / max(n_dev // tp, 1)
        weights = P_tot * (3 * pb + 8 + opt_b) / n_dev
    elif shape.kind == "prefill":
        tokens_loc = B * S / max(n_dev // tp, 1)
        weights = P_tot * pb / n_dev
    else:
        tokens_loc = max(B / max(n_dev // tp, 1), 1)
        weights = P_act * pb / n_dev
    d_ff_eff = cfg.d_ff + (cfg.experts_per_token * cfg.moe_d_ff
                           if cfg.n_experts else 0)
    attn_dim = (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim
    per_tok_layer = (8 * cfg.d_model + 3 * d_ff_eff + attn_dim) * 2
    act_factor = 4.0 if shape.kind == "train" else 1.5
    n_layers = cfg.n_layers + (cfg.n_encoder_layers or 0)
    acts = tokens_loc * per_tok_layer * n_layers * act_factor / tp
    logits = tokens_loc * cfg.padded_vocab * 4 * 3 / tp
    cache = 0.0
    if shape.kind == "decode":
        ctx = min(S, cfg.sliding_window or S)
        if cfg.local_window:
            ctx = min(ctx, max(cfg.local_window,
                               S if "global" in cfg.pattern else 0)) or ctx
        kv_per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * 2
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if cfg.pattern[i % len(cfg.pattern)]
                     in ("attn", "local", "global"))
        cache = (B / max(n_dev // tp, 1)) * ctx * kv_per_tok * n_attn / tp
        if cfg.family == "ssm":
            cache = (B / max(n_dev // tp, 1)) * cfg.n_layers * \
                cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4 * 2 / tp
    return weights + acts + logits + cache


def _layer_window(cfg, kind: str):
    if kind == "local":
        return cfg.local_window
    if kind in ("attn", "global"):
        return cfg.sliding_window
    return None


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N_active per train token, 2*N_active per inference
    token, plus the attention term 4*H*dh*avg_ctx per token per attention
    layer (avg_ctx respects each layer kind's window)."""
    n_active = cfg.n_active_params()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        base = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = B * S
        base = 2.0 * n_active * tokens
    else:
        tokens = B  # one new token per sequence
        base = 2.0 * n_active * tokens
    if cfg.n_heads:
        dh, Hq = cfg.head_dim, cfg.n_heads
        bwd = 3.0 if shape.kind == "train" else 1.0
        for i in range(cfg.n_layers):
            kind = cfg.pattern[i % len(cfg.pattern)]
            if kind not in ("attn", "local", "global"):
                continue
            w = _layer_window(cfg, kind)
            if shape.kind == "decode":
                ctx = min(S, w) if w else S
                base += 4.0 * Hq * dh * ctx * B
            else:
                weff = min(w, S) if w else S
                avg_ctx = weff * (S - weff / 2.0) / S  # ->S/2 full, ->w long
                base += bwd * 4.0 * Hq * dh * avg_ctx * B * S
    return base


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--roofline", action="store_true",
                    help="2-point extrapolated roofline instead of the "
                         "full-depth compile-validation cell")
    ap.add_argument("--layout", default="baseline",
                    help="baseline | seqpar | zero3 | moe_ep | auto "
                         "(hillclimbed presets, see launch/presets.py)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(make_production_mesh(multi_pod=False))
    if args.mesh in ("multi", "both"):
        meshes.append(make_production_mesh(multi_pod=True))

    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]

    kind = "roofline" if args.roofline else "dryrun"
    for mesh in meshes:
        tag = _mesh_tag(mesh)
        for arch in archs:
            for shape_name in shapes:
                path = os.path.join(
                    args.out, f"{arch}__{shape_name}__{tag}"
                    + ("__roofline" if args.roofline else "") + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip existing] {path}")
                    continue
                print(f"=== [{kind}] {arch} x {shape_name} on {tag} ===",
                      flush=True)
                rules = rt_o = tc_o = None
                if args.layout != "baseline":
                    from .presets import resolve_layout

                    rules, rt_o, tc_o = resolve_layout(
                        get_config(arch), SHAPES[shape_name], mesh,
                        args.layout)
                rec = (run_cell_roofline(arch, shape_name, mesh,
                                         rules=rules, rt_overrides=rt_o)
                       if args.roofline else
                       run_cell(arch, shape_name, mesh, rules=rules,
                                rt_overrides=rt_o, tc_overrides=tc_o))
                rec["layout"] = args.layout
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    mem = rec.get("memory", {})
                    extra = (f" dominant={r['dominant']}"
                             f" compute={r['compute_s']:.4f}s"
                             f" memory={r['memory_s']:.4f}s"
                             f" coll={r['collective_s']:.4f}s")
                    if mem:
                        extra += (" peak="
                                  f"{mem.get('peak_estimate_bytes', 0)/2**30:.2f}GiB")
                    if "roofline_fraction" in rec:
                        extra += f" roofline_frac={rec['roofline_fraction']:.3f}"
                    if "compile_s" in rec:
                        extra += f" compile={rec['compile_s']}s"
                elif status == "error":
                    extra = " " + rec["error"][:200]
                print(f"    -> {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
