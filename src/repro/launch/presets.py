"""Layout presets: the §Perf hillclimb winners as selectable configs.

``resolve_layout(cfg, shape, mesh, layout)`` returns (ShardingRules,
rt_overrides, tc_overrides).  ``layout="auto"`` picks the measured-best
per workload family:

- prefill / long-context   -> context-parallel attention (seq over tp)
- dense train              -> ZeRO-3 (fsdp over both axes), dots remat
- MoE train                -> shard_map expert parallelism + ZeRO-3 dense
- decode / small models    -> baseline TP x FSDP

EXPERIMENTS.md §Perf records the measurements behind each rule.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

from ..configs.base import ModelConfig, ShapeConfig
from ..train.sharding import ShardingRules

__all__ = ["LAYOUTS", "resolve_layout"]

LAYOUTS = ("baseline", "seqpar", "zero3", "moe_ep", "auto")


def resolve_layout(cfg: ModelConfig, shape: ShapeConfig, mesh,
                   layout: str = "auto"
                   ) -> Tuple[ShardingRules, Dict[str, Any], Dict[str, Any]]:
    if layout == "auto":
        if cfg.n_experts and shape.kind == "train":
            layout = "moe_ep"
        elif shape.kind == "train" and cfg.n_params() > 5e9:
            layout = "zero3"
        elif shape.kind == "prefill":
            layout = "seqpar"
        else:
            layout = "baseline"

    if layout == "baseline":
        return ShardingRules(mesh), {}, {}
    if layout == "seqpar":
        return (ShardingRules(mesh, attn_shard_mode="seq"),
                {"constrain_attn_heads": True}, {})
    n_dev = mesh.devices.size
    # ZeRO-3 wants one batch row per chip; when global_batch < chips (the
    # multi-pod mesh), shard the SEQUENCE over the model axis instead
    # (ZeRO-3 + sequence parallelism, DeepSpeed-Ulysses style).
    seq_par = shape.global_batch % n_dev != 0
    if layout == "zero3":
        rules = ShardingRules(
            mesh, tp_axis=None, fsdp_axis=("data", "model"),
            batch_axes=(("pod", "data") if seq_par
                        else ("pod", "data", "model")),
            seq_axis="model" if seq_par else None,
            attn_shard_mode="seq" if seq_par else "heads")
        rt = {"remat": "dots"}
        if seq_par:
            rt["constrain_attn_heads"] = True
        return rules, rt, {"microbatches": 1}
    if layout == "moe_ep":
        rules = ShardingRules(
            mesh, tp_axis=None, fsdp_axis=("data", "model"),
            batch_axes=(("pod", "data") if seq_par
                        else ("pod", "data", "model")),
            seq_axis="model" if seq_par else None,
            attn_shard_mode="seq" if seq_par else "heads")
        rt = {"moe_impl": "shard_map", "remat": "full"}
        if seq_par:
            rt["constrain_attn_heads"] = True
        return (rules, rt, {"microbatches": 1})
    raise ValueError(f"unknown layout {layout!r}; choose from {LAYOUTS}")
