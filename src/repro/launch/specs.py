"""ShapeDtypeStruct stand-ins for every model input (no allocation), plus
per-cell runtime/optimizer/microbatch policy.

``input_specs(cfg, shape)`` mirrors what the data pipeline emits for that
architecture family; the dry-run lowers against these.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models import RuntimeConfig
from ..train.optimizer import OptimizerConfig
from ..train.step import TrainConfig

__all__ = ["input_specs", "runtime_for", "train_config_for",
           "pick_microbatches"]

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Training-batch stand-ins: {tokens, labels, segments, positions,
    [frontend_embeds]} sized for (arch x shape)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    emb = jnp.bfloat16
    if cfg.is_encoder_decoder:
        return {
            "tokens": SDS((B, S), i32),
            "labels": SDS((B, S), i32),
            "frontend_embeds": SDS((B, S, cfg.d_model), emb),
        }
    batch: Dict[str, Any] = {}
    if cfg.frontend == "vision":
        P = cfg.frontend_tokens
        S_text = S - P
        batch["frontend_embeds"] = SDS((B, P, cfg.d_model), emb)
        batch["tokens"] = SDS((B, S_text), i32)
        batch["labels"] = SDS((B, S_text), i32)
        batch["segments"] = SDS((B, S), i32)     # full length (prefix incl.)
        batch["positions"] = SDS((B, S), i32)
    else:
        batch["tokens"] = SDS((B, S), i32)
        batch["labels"] = SDS((B, S), i32)
        batch["segments"] = SDS((B, S), i32)
        batch["positions"] = SDS((B, S), i32)
    return batch


def serve_token_specs(cfg: ModelConfig, shape: ShapeConfig):
    B = shape.global_batch
    return SDS((B, 1), jnp.int32), SDS((), jnp.int32)


def runtime_for(cfg: ModelConfig, shape: ShapeConfig,
                **overrides) -> RuntimeConfig:
    big = cfg.n_params() > 5e9
    rt = RuntimeConfig(
        param_dtype=jnp.bfloat16 if big else jnp.float32,
        compute_dtype=jnp.bfloat16,
        attn_impl="xla",             # chunked flash (CPU dry-run lowering)
        ssd_impl="xla",
        rglru_impl="xla",
        remat="full" if shape.kind == "train" else "none",
        scan_layers=True,
        attn_block_q=512,
        attn_block_k=1024,
        moe_group_size=512,
        max_cache_len=shape.seq_len if shape.kind == "decode" else shape.seq_len,
    )
    return rt.with_(**overrides) if overrides else rt


def pick_microbatches(cfg: ModelConfig, shape: ShapeConfig,
                      data_parallel: int) -> int:
    """Per-device-per-microbatch token target keeps activations in HBM."""
    if shape.kind != "train":
        return 1
    b_loc = max(1, shape.global_batch // data_parallel)
    tokens_loc = b_loc * shape.seq_len
    n = cfg.n_params()
    target = 8_192 if n > 2e10 else 16_384
    micro = max(1, tokens_loc // target)
    micro = min(micro, b_loc)
    while b_loc % micro:
        micro -= 1
    return micro


def train_config_for(cfg: ModelConfig, shape: ShapeConfig,
                     data_parallel: int, **opt_overrides) -> TrainConfig:
    n = cfg.n_params()
    opt = OptimizerConfig(
        name="adafactor" if n > 1e11 else "adamw",
        lr=3e-4, grad_clip=1.0,
        **opt_overrides,
    )
    return TrainConfig(
        optimizer=opt,
        microbatches=pick_microbatches(cfg, shape, data_parallel),
    )
