"""ML-specific pipeline components: tokenize, pack, split, dedup, filter.

These are the paper's "transform the original data to get a derived version
of the dataset" made concrete for LM training: text records in, fixed-length
packed token sequences out — the snapshot a training job checks out.
"""

from __future__ import annotations

import hashlib
import io
import json
import struct
from typing import Iterable, Iterator, List, Optional, Tuple

import numpy as np

from ..core.dataset import Record
from ..core.transforms import Component, RunContext

__all__ = ["ByteTokenizer", "TokenizeComponent", "PackComponent",
           "SplitComponent", "DedupComponent", "LengthFilterComponent",
           "encode_packed", "decode_packed"]

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
_SPECIALS = 3


class ByteTokenizer:
    """Deterministic byte-level tokenizer (vocab = 256 + specials).

    Production swaps in a learned BPE via the same interface; for platform/
    training tests a dependency-free reversible tokenizer is the right tool.
    """

    vocab_size = 256 + _SPECIALS

    def encode(self, text: bytes, add_bos: bool = True,
               add_eos: bool = True) -> List[int]:
        ids = [b + _SPECIALS for b in text]
        if add_bos:
            ids = [BOS_ID] + ids
        if add_eos:
            ids = ids + [EOS_ID]
        return ids

    def decode(self, ids) -> bytes:
        return bytes(int(i) - _SPECIALS for i in ids
                     if int(i) >= _SPECIALS)


class TokenizeComponent(Component):
    """text record -> token-array record (.npy payload)."""

    per_record = True  # record-wise + deterministic: incremental-safe

    def __init__(self, tokenizer: Optional[ByteTokenizer] = None,
                 name: str = "tokenize") -> None:
        super().__init__(name=name)
        self.tok = tokenizer or ByteTokenizer()

    def process(self, records, ctx: RunContext) -> Iterator[Record]:
        for rec in records:
            ids = np.asarray(self.tok.encode(rec.data), np.int32)
            buf = io.BytesIO()
            np.save(buf, ids, allow_pickle=False)
            ctx.bump(f"{self.name}.tokens", float(ids.size))
            yield Record(rec.record_id, buf.getvalue(),
                         {**rec.attrs, "n_tokens": int(ids.size),
                          "format": "tokens.npy"})


class PackComponent(Component):
    """Token records -> packed fixed-length sequences with segment ids.

    Documents are concatenated greedily; each output record holds
    ``tokens``, ``segments`` (per-token document index within the pack) and
    ``positions`` (restarting at each document) plus the source record ids
    (lineage at *record* granularity: revoking a source doc identifies the
    packs that contain it).
    """

    def __init__(self, seq_len: int, name: str = "pack") -> None:
        super().__init__(name=name, seq_len=seq_len)
        self.seq_len = seq_len

    def process(self, records, ctx: RunContext) -> Iterator[Record]:
        L = self.seq_len + 1          # +1 so tokens/labels both get seq_len
        buf_tokens: List[int] = []
        buf_segments: List[int] = []
        buf_positions: List[int] = []
        buf_sources: List[str] = []
        seg = 0
        out_idx = 0

        def flush():
            nonlocal buf_tokens, buf_segments, buf_positions, buf_sources, \
                seg, out_idx
            toks = np.asarray(buf_tokens[:L], np.int32)
            segs = np.asarray(buf_segments[:L], np.int32)
            pos = np.asarray(buf_positions[:L], np.int32)
            if toks.size < L:
                pad = L - toks.size
                toks = np.pad(toks, (0, pad), constant_values=PAD_ID)
                segs = np.pad(segs, (0, pad), constant_values=-1)
                pos = np.pad(pos, (0, pad))
            rec = Record(
                f"pack-{ctx.shard_index:03d}-{out_idx:06d}",
                encode_packed(toks, segs, pos),
                {"format": "packed.bin", "seq_len": self.seq_len,
                 "sources": json.dumps(buf_sources)})
            buf_tokens = buf_tokens[L:]
            buf_segments = buf_segments[L:]
            buf_positions = buf_positions[L:]
            buf_sources = []
            out_idx += 1
            return rec

        for rec in records:
            ids = np.load(io.BytesIO(rec.data), allow_pickle=False)
            buf_tokens.extend(int(i) for i in ids)
            buf_segments.extend([seg] * ids.size)
            buf_positions.extend(range(ids.size))
            buf_sources.append(rec.record_id)
            seg += 1
            while len(buf_tokens) >= L:
                ctx.bump(f"{self.name}.packs")
                yield flush()
        if buf_tokens:
            ctx.bump(f"{self.name}.packs")
            yield flush()


class SplitComponent(Component):
    """Deterministically assign split attrs by record-id hash."""

    per_record = True

    def __init__(self, eval_fraction: float = 0.05, name: str = "split"):
        super().__init__(name=name, eval_fraction=eval_fraction)
        self.eval_fraction = eval_fraction

    def process(self, records, ctx):
        for rec in records:
            h = int(hashlib.sha256(rec.record_id.encode()).hexdigest()[:8], 16)
            split = "eval" if (h % 10_000) < self.eval_fraction * 10_000 \
                else "train"
            yield Record(rec.record_id, rec.data, {**rec.attrs, "split": split})


class DedupComponent(Component):
    """Exact-content dedup (content hash) — classic data-cleanup stage."""

    def __init__(self, name: str = "dedup"):
        super().__init__(name=name)

    def process(self, records, ctx):
        seen = set()
        for rec in records:
            h = hashlib.sha256(rec.data).hexdigest()
            if h in seen:
                ctx.bump(f"{self.name}.dropped")
                continue
            seen.add(h)
            yield rec


class LengthFilterComponent(Component):
    per_record = True

    def __init__(self, min_bytes: int = 1, max_bytes: int = 1 << 20,
                 name: str = "length_filter"):
        super().__init__(name=name, min_bytes=min_bytes, max_bytes=max_bytes)
        self.min_bytes, self.max_bytes = min_bytes, max_bytes

    def process(self, records, ctx):
        for rec in records:
            if self.min_bytes <= len(rec.data) <= self.max_bytes:
                yield rec
            else:
                ctx.bump(f"{self.name}.dropped")


# Packed-sequence payload format.  v1 datasets stored ``.npz`` blobs, but
# ``np.load``'s zipfile parsing costs ~700us per record — far more than the
# loader's entire per-batch budget — so packs are now a raw header + three
# little-endian int32 arrays.  ``decode_packed`` sniffs the magic and falls
# back to npz so pre-existing checked-in datasets stay readable.
_PACK_MAGIC = b"RPK1"
_PACK_HDR = struct.Struct("<4sI")


def encode_packed(tokens: np.ndarray, segments: np.ndarray,
                  positions: np.ndarray) -> bytes:
    """Serialize one packed sequence (three equal-length int32 arrays)."""
    n = len(tokens)
    if len(segments) != n or len(positions) != n:
        raise ValueError("packed arrays must share one length")
    return (_PACK_HDR.pack(_PACK_MAGIC, n)
            + np.ascontiguousarray(tokens, "<i4").tobytes()
            + np.ascontiguousarray(segments, "<i4").tobytes()
            + np.ascontiguousarray(positions, "<i4").tobytes())


def decode_packed(data: bytes) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    if data[:4] == _PACK_MAGIC:
        (_, n) = _PACK_HDR.unpack_from(data)
        arr = np.frombuffer(data, dtype="<i4", count=3 * n,
                            offset=_PACK_HDR.size)
        return arr[:n], arr[n:2 * n], arr[2 * n:]
    z = np.load(io.BytesIO(data), allow_pickle=False)  # legacy npz payloads
    return z["tokens"], z["segments"], z["positions"]
