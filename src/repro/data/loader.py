"""Sharded, deterministic, resumable loader: platform checkout -> device
batches.

Feed it a materialized :class:`~repro.core.dataset.Snapshot` or — the
preferred, allocation-free path — a lazy
:class:`~repro.core.dataset.CheckoutPlan` straight from
``Platform.open(...).dataset(name).plan(where=...)``: the loader only needs
the Snapshot-like read surface, which a plan streams from the manifest
without materializing a snapshot or registering lineage for every restart.

This is the handoff between the paper's data plane and the TPU fleet:

- **Deterministic order**: the batch stream is a pure function of
  (snapshot digest, epoch, seed, step) — the property that makes
  checkpoint/restart exact (no skipped/duplicated data after preemption).
  Two shuffle modes share that contract:

  * ``shuffle="global"`` — the legacy full permutation: every record id is
    hashed with (seed, epoch) and the whole epoch is sorted at once.
    Exact, but O(N) resident ids and an O(N log N) sort per epoch — the
    measurable baseline, and the default for small snapshots.
  * ``shuffle="page_window"`` — page-window streaming: the commit's
    manifest *pages* are deterministically permuted per (epoch, seed),
    consecutive permuted pages are grouped into windows of
    ``window_pages`` pages, and records are shuffled (same seeded-hash
    sort) *within* each window.  The full permutation is never
    materialized: peak resident ids are O(window_pages · page_size)
    regardless of snapshot size, and a window with ``window_pages >=
    n_pages`` degenerates to exactly the global order.  Requires the
    page-granular feed surface (``page_count`` / ``page_sizes`` /
    ``page_entries`` / ``read_entries`` / ``pages_digest``), which
    CheckoutPlan serves straight from the page directory for pure plans.

- **Sharded**: shard ``i`` of ``n`` reads records where
  ``order_index % n == i`` — in a multi-host job each host feeds only its
  slice and ``jax.make_array_from_process_local_data`` assembles the global
  array; single-process here, we assemble directly with ``device_put``.
- **Resumable**: ``state()`` is a tiny dict (snapshot digest, shuffle mode,
  epoch, step, window cursor) stored inside checkpoints; ``restore()``
  seeks exactly there — in page-window mode the seek costs O(window), not
  a replay of the epoch.
- **Pipelined host stage**: iteration decodes/stacks batches on a small
  worker pool feeding a bounded in-order queue; ``stats()`` reports
  ``wait_fraction`` — the share of consumer wall time spent blocked on the
  queue — so a feed that can't keep a device busy is measurable, not a
  mystery.  A stuck shard surfaces as a descriptive ``TimeoutError``
  (snapshot digest, shard, epoch, step), never a raw ``queue.Empty``.
- **Double-buffered device transfer**: :class:`DeviceFeed` wraps the
  iterator with a depth-2 device-side buffer — the next batch's
  ``device_put`` (one call for the whole pytree) is issued while the
  current ``train_step`` runs, so the step loop never blocks on host work.
"""

from __future__ import annotations

import bisect
import collections
import concurrent.futures as cf
import hashlib
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dataset import CheckoutPlan, Snapshot
from .components import decode_packed

__all__ = ["ShardedSnapshotLoader", "DeviceFeed", "LoaderState"]

SnapshotLike = Union[Snapshot, CheckoutPlan]

LoaderState = Dict[str, Any]

# Feed-surface methods a snapshot must expose for page-window mode.
_PAGE_SURFACE = ("page_count", "page_sizes", "read_pages", "read_entries",
                 "pages_digest", "count")


def _order(record_ids: List[str], epoch: int, seed: int) -> List[str]:
    """Reference epoch ordering — records sorted by seeded per-record hash.

    Kept as the executable spec: :func:`_order_fast` must stay bit-identical
    to this (the golden determinism suite pins it), or existing checkpoints
    would silently restore onto different batch streams.
    """
    def key(rid: str) -> str:
        return hashlib.sha256(f"{seed}:{epoch}:{rid}".encode()).hexdigest()

    return sorted(record_ids, key=key)


def _order_fast(record_ids: List[str], epoch: int, seed: int) -> List[str]:
    """Same permutation as :func:`_order`, computed vectorized.

    Hashes every id in one pass (the sha256 per record is load-bearing —
    it IS the ordering key), then argsorts the packed digest matrix with
    ``np.lexsort``.  Sorting by raw digest bytes equals sorting by
    ``hexdigest()`` because hex encoding is monotone bytewise; lexsort over
    the four big-endian u64 columns equals bytewise comparison of the
    32-byte digests, and both sorts are stable, so ties (impossible for
    distinct ids in practice) break identically.
    """
    if not record_ids:
        return []
    prefix = f"{seed}:{epoch}:".encode()
    sha = hashlib.sha256
    digests = b"".join(sha(prefix + rid.encode()).digest()
                       for rid in record_ids)
    cols = np.frombuffer(digests, dtype=">u8").reshape(-1, 4)
    perm = np.lexsort((cols[:, 3], cols[:, 2], cols[:, 1], cols[:, 0]))
    return [record_ids[i] for i in perm]


def _page_perm(n_pages: int, epoch: int, seed: int) -> List[int]:
    """Deterministic page permutation — same seeded-hash sort as
    :func:`_order`, keyed on the page's position in the directory (pages
    are content-addressed, so position is stable for a fixed snapshot)."""
    sha = hashlib.sha256
    prefix = f"{seed}:{epoch}:page:".encode()
    return sorted(range(n_pages),
                  key=lambda pi: sha(prefix + str(pi).encode()).digest())


class ShardedSnapshotLoader:
    # How many (epoch, group) windows stay resident: the active window, its
    # neighbor (a batch may straddle a group boundary), and headroom for
    # decode workers prefetching the next batch.  This bound IS the
    # page-window memory contract: peak resident ids <=
    # _GROUP_CACHE_CAP * window_pages * page_size.
    _GROUP_CACHE_CAP = 3

    def __init__(
        self,
        snapshot: SnapshotLike,
        batch_size: int,
        seq_len: int,
        shard_id: int = 0,
        n_shards: int = 1,
        seed: int = 0,
        prefetch: int = 2,
        timeout_s: float = 60.0,
        cache_epoch_orders: bool = True,
        shuffle: str = "auto",
        window_pages: int = 8,
        decode_workers: int = 2,
        auto_page_window_min: int = 100_000,
    ):
        assert batch_size % n_shards == 0
        if shuffle not in ("auto", "global", "page_window"):
            raise ValueError(f"unknown shuffle mode {shuffle!r}")
        self.snapshot = snapshot
        self.batch = batch_size
        self.local_batch = batch_size // n_shards
        self.seq_len = seq_len
        self.shard_id = shard_id
        self.n_shards = n_shards
        self.seed = seed
        self.prefetch = prefetch
        self.timeout_s = timeout_s
        self.window_pages = int(window_pages)
        self.decode_workers = max(1, int(decode_workers))
        self.epoch = 0
        self.step = 0
        # ``cache_epoch_orders=False`` restores the pre-cache behaviour
        # (recompute the permutation every batch) — benchmark baseline only.
        self.cache_epoch_orders = cache_epoch_orders
        self._ids: Optional[List[str]] = None
        self._n: Optional[int] = None
        self._order_cache: Dict[tuple, List[str]] = {}
        # page-window state: per-(epoch, seed) page plan + resident windows
        self._page_plan_cache: Dict[tuple, Tuple[List[List[int]], List[int]]] = {}
        self._groups: "collections.OrderedDict[tuple, Tuple[List[str], Dict[str, Any]]]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self._stats: Dict[str, float] = {
            "batches": 0, "wait_time_s": 0.0, "run_time_s": 0.0,
            "read_time_s": 0.0, "decode_time_s": 0.0,
            "pages_streamed": 0, "resident_ids": 0, "peak_resident_ids": 0,
        }
        has_pages = all(hasattr(snapshot, m) for m in _PAGE_SURFACE)
        if shuffle == "page_window":
            if not has_pages:
                raise ValueError(
                    "shuffle='page_window' needs the page-granular feed "
                    "surface (CheckoutPlan / Snapshot); this snapshot lacks "
                    f"{[m for m in _PAGE_SURFACE if not hasattr(snapshot, m)]}")
            self._mode = "page_window"
        elif shuffle == "global" or not has_pages:
            self._mode = "global"
        else:  # auto: stream only when the full permutation would hurt
            self._mode = ("page_window"
                          if int(snapshot.count()) >= auto_page_window_min
                          else "global")
        # Content identity: page-window feeds hash the page directory rows
        # (O(pages), no record materialization); global mode keeps the exact
        # legacy per-record digest so existing checkpoints keep restoring.
        self._content = (snapshot.pages_digest() if self._mode == "page_window"
                         else snapshot.content_digest())

    # ---------------------------------------------------------------- state

    def state(self) -> LoaderState:
        st: LoaderState = {"snapshot_content": self._content,
                           "epoch": self.epoch, "step": self.step,
                           "seed": self.seed, "shuffle": self._mode}
        if self._mode == "page_window":
            st["window_pages"] = self.window_pages
            per = self._per_epoch()
            pos = (self.step % per) * self.batch if per else 0
            groups, cum = self._page_plan(self.step // per if per else 0)
            g = min(bisect.bisect_right(cum, pos) - 1, len(groups) - 1)
            st["cursor"] = {"group": g, "offset": pos - cum[g]}
        return st

    def restore(self, state: LoaderState) -> None:
        mode = state.get("shuffle", "global")
        if mode != self._mode:
            raise ValueError(
                f"loader restore across shuffle modes: checkpoint was "
                f"{mode!r}, this loader is {self._mode!r} — the batch "
                "streams differ (refusing silent data drift)")
        if self._mode == "page_window" and \
                int(state.get("window_pages", -1)) != self.window_pages:
            raise ValueError(
                "loader restore with a different window_pages "
                f"({state.get('window_pages')} != {self.window_pages}) — "
                "the in-window shuffle differs (refusing silent data drift)")
        if state["snapshot_content"] != self._content:
            raise ValueError(
                "loader restore onto a different snapshot: "
                f"{state['snapshot_content'][:12]} != {self._content[:12]} "
                "(lineage mismatch — refusing silent data drift)")
        self.epoch = int(state["epoch"])
        self.step = int(state["step"])
        self.seed = int(state["seed"])

    # ---------------------------------------------------------------- order

    def _record_ids(self) -> List[str]:
        if self._ids is None:
            self._ids = list(self.snapshot.record_ids())
        return self._ids

    def _count(self) -> int:
        if self._n is None:
            if self._mode == "page_window":
                self._n = int(self.snapshot.count())
            else:
                self._n = len(self._record_ids())
        return self._n

    def _per_epoch(self) -> int:
        return self._count() // self.batch     # drop ragged tail

    def _epoch_order(self, epoch: int) -> List[str]:
        """Deterministic epoch permutation, computed once per (epoch, seed).

        The per-batch cost drops from O(N) hashing + O(N log N) sorting to
        a dict hit; ordering stays bit-identical to :func:`_order` (golden
        tests), so checkpoints restore onto identical batch streams.
        """
        if not self.cache_epoch_orders:
            return _order(self._record_ids(), epoch, self.seed)
        key = (epoch, self.seed)
        with self._lock:
            order = self._order_cache.get(key)
            if order is None:
                order = _order_fast(self._record_ids(), epoch, self.seed)
                # keep the current and previous epoch only (restore() can
                # step back); anything older is dead weight
                self._order_cache = {
                    k: v for k, v in self._order_cache.items()
                    if k[0] >= epoch - 1 and k[1] == self.seed}
                self._order_cache[key] = order
        return order

    # -------------------------------------------------------- page windows

    def _page_plan(self, epoch: int) -> Tuple[List[List[int]], List[int]]:
        """(window groups, cumulative record offsets) for one epoch.

        Pure directory metadata — page counts come from ``page_sizes()``,
        so seeking to any stream position never reads a page.  Groups are
        consecutive runs of ``window_pages`` pages of the per-epoch page
        permutation; ``cum[g]`` is the global stream position of group
        ``g``'s first record.
        """
        key = (epoch, self.seed)
        with self._lock:
            hit = self._page_plan_cache.get(key)
            if hit is not None:
                return hit
            sizes = list(self.snapshot.page_sizes())
            perm = _page_perm(len(sizes), epoch, self.seed)
            W = max(1, self.window_pages)
            groups = [perm[i:i + W] for i in range(0, len(perm), W)]
            cum = [0]
            for grp in groups:
                cum.append(cum[-1] + sum(sizes[pi] for pi in grp))
            self._page_plan_cache = {
                k: v for k, v in self._page_plan_cache.items()
                if k[0] >= epoch - 1 and k[1] == self.seed}
            self._page_plan_cache[key] = (groups, cum)
            return groups, cum

    def _window(self, epoch: int, g: int) -> Tuple[List[str], Dict[str, Any]]:
        """One resident window: (in-window record order, id -> entry map).

        Loads the group's pages through the feed surface (grouped CAS
        reads under the hood) and shuffles records *within* the window with
        the same seeded-hash sort as global mode — so a window covering
        every page IS the global permutation.  Bounded LRU keeps peak
        resident ids at O(window_pages · page_size).
        """
        key = (epoch, self.seed, g)
        with self._lock:
            hit = self._groups.get(key)
            if hit is not None:
                self._groups.move_to_end(key)
                return hit
        groups, _ = self._page_plan(epoch)
        entries: Dict[str, Any] = {}
        for page in self.snapshot.read_pages(groups[g]):
            for e in page:
                entries[e.record_id] = e
        order = _order_fast(list(entries), epoch, self.seed)
        with self._lock:
            self._groups[key] = (order, entries)
            self._groups.move_to_end(key)
            while len(self._groups) > self._GROUP_CACHE_CAP:
                self._groups.popitem(last=False)
            resident = sum(len(o) for o, _ in self._groups.values())
            self._stats["pages_streamed"] += len(groups[g])
            self._stats["resident_ids"] = resident
            self._stats["peak_resident_ids"] = max(
                self._stats["peak_resident_ids"], resident)
        return order, entries

    def _stream_entries(self, epoch: int, positions: List[int]) -> List[Any]:
        """Entries at the given global stream positions (page-window mode)."""
        groups, cum = self._page_plan(epoch)
        out = []
        for pos in positions:
            g = min(bisect.bisect_right(cum, pos) - 1, len(groups) - 1)
            order, entries = self._window(epoch, g)
            out.append(entries[order[pos - cum[g]]])
        return out

    # ---------------------------------------------------------------- batches

    def _decode_row(self, payload: bytes) -> Dict[str, np.ndarray]:
        tokens, segments, positions = decode_packed(payload)
        L = self.seq_len
        return {
            "tokens": tokens[:L], "labels": tokens[1:L + 1],
            "segments": segments[:L], "positions": positions[:L],
        }

    def _read(self, rid: str) -> Dict[str, np.ndarray]:
        return self._decode_row(self.snapshot.read(rid))

    def _read_rows(self, rids: List[str]) -> List[Dict[str, np.ndarray]]:
        reader = getattr(self.snapshot, "read_batch", None)
        if reader is not None:
            return [self._decode_row(buf) for buf in reader(rids)]
        return [self._read(rid) for rid in rids]

    def _batch_at(self, gstep: int) -> Dict[str, np.ndarray]:
        """The local (per-shard) slice of global batch ``gstep`` — a pure
        function of (snapshot, seed, gstep), safe to compute on any worker
        thread in any order."""
        per_epoch = self._per_epoch()
        if per_epoch == 0:
            raise ValueError("snapshot smaller than one global batch")
        epoch, step_in_epoch = divmod(gstep, per_epoch)
        base = step_in_epoch * self.batch
        positions = [base + self.shard_id + j * self.n_shards
                     for j in range(self.local_batch)]
        t0 = time.perf_counter()
        if self._mode == "page_window":
            entries = self._stream_entries(epoch, positions)
            payloads = self.snapshot.read_entries(entries)
            t1 = time.perf_counter()
            rows = [self._decode_row(buf) for buf in payloads]
        else:
            order = self._epoch_order(epoch)
            rids = [order[p] for p in positions]
            t1 = time.perf_counter()
            rows = self._read_rows(rids)
        t2 = time.perf_counter()
        out = {k: np.stack([r[k] for r in rows]) for k in rows[0]}
        # mask labels at padding (segment -1)
        out["labels"] = np.where(out["segments"] >= 0, out["labels"], -1)
        t3 = time.perf_counter()
        with self._lock:
            self._stats["read_time_s"] += t1 - t0
            self._stats["decode_time_s"] += (t2 - t1) + (t3 - t2)
        return out

    def _note_delivered(self, gstep: int) -> None:
        self.step = gstep + 1
        self.epoch = gstep // self._per_epoch()

    def next_batch(self) -> Dict[str, np.ndarray]:
        """The local (per-shard) slice of global batch ``self.step``."""
        gstep = self.step
        out = self._batch_at(gstep)
        self._note_delivered(gstep)
        with self._lock:
            self._stats["batches"] += 1
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        """Pipelined iteration: batches are computed on a decode worker
        pool, delivered strictly in order through a bounded queue of
        in-flight futures.  Consumer blocked-time is accounted as
        ``wait_time_s`` (vs ``run_time_s`` spent in the consumer's own
        code), which :meth:`stats` turns into ``wait_fraction``.
        """
        pool = cf.ThreadPoolExecutor(max_workers=self.decode_workers,
                                     thread_name_prefix="loader-decode")
        depth = max(1, self.prefetch)
        pending: "collections.deque" = collections.deque()
        next_step = self.step
        timed_out = False
        t_last = time.perf_counter()
        try:
            while True:
                while len(pending) < depth:
                    pending.append(
                        (next_step, pool.submit(self._batch_at, next_step)))
                    next_step += 1
                gstep, fut = pending.popleft()
                t0 = time.perf_counter()
                try:
                    batch = fut.result(timeout=self.timeout_s)
                except (TimeoutError, cf.TimeoutError):
                    if fut.done():   # the batch itself raised TimeoutError
                        raise
                    timed_out = True
                    per = max(1, self._per_epoch())
                    raise TimeoutError(
                        f"loader shard stuck: no batch within "
                        f"{self.timeout_s:.1f}s (snapshot "
                        f"{self._content[:12]}, shard {self.shard_id}/"
                        f"{self.n_shards}, epoch {gstep // per}, "
                        f"step {gstep})") from None
                t1 = time.perf_counter()
                self._note_delivered(gstep)
                with self._lock:
                    self._stats["batches"] += 1
                    self._stats["wait_time_s"] += t1 - t0
                    self._stats["run_time_s"] += t0 - t_last
                yield batch
                t_last = time.perf_counter()
        finally:
            for _, fut in pending:
                fut.cancel()
            # A genuinely stuck read can't be joined — leave it to the
            # daemon-less pool thread and don't hang the consumer's exit.
            pool.shutdown(wait=not timed_out, cancel_futures=True)

    # ---------------------------------------------------------------- stats

    def stats(self) -> Dict[str, Any]:
        """Feed health counters.

        ``wait_fraction`` is the share of consumer wall time spent blocked
        on the prefetch queue during iteration (0.0 == the device never
        waited on host work); ``pages_streamed`` / ``peak_resident_ids``
        expose the page-window accounting the memory contract is tested
        against."""
        with self._lock:
            s: Dict[str, Any] = dict(self._stats)
        busy = s["wait_time_s"] + s["run_time_s"]
        s["wait_fraction"] = (s["wait_time_s"] / busy) if busy > 0 else 0.0
        s["mode"] = self._mode
        s["window_pages"] = self.window_pages if self._mode == "page_window" \
            else None
        return s

    # ---------------------------------------------------------------- device

    def device_batch(self, batch: Dict[str, np.ndarray], mesh, specs
                     ) -> Dict[str, jnp.ndarray]:
        """Lay a host batch onto the mesh per the given PartitionSpecs."""
        from jax.sharding import NamedSharding

        return {
            k: jax.device_put(v, NamedSharding(mesh, specs[k]))
            for k, v in batch.items()
        }


class DeviceFeed:
    """Depth-``depth`` double-buffered host→device feed over a loader.

    Pulls host batches from the loader's pipelined iterator, issues ONE
    ``jax.device_put`` for the whole batch pytree (donating leaves that are
    already device arrays), and keeps ``depth`` transferred batches in
    flight — ``device_put`` dispatch is asynchronous, so the next batch's
    transfer overlaps the current ``train_step``.  Yields ``(device_batch,
    loader_state)`` pairs: the paired state is taken exactly when the host
    batch was consumed, so checkpointing it restores onto a bit-identical
    stream even while later batches are already buffered on device.

    ``shardings`` is a pytree of shardings matching the batch (or a single
    sharding); alternatively ``sharding_fn(host_batch)`` builds it lazily
    from the first batch (the usual route via ``batch_specs``).  With
    neither, batches land on the default device.
    """

    def __init__(self, loader: ShardedSnapshotLoader, shardings=None,
                 sharding_fn=None, depth: int = 2, donate: bool = True):
        self.loader = loader
        self.depth = max(1, int(depth))
        self.donate = donate
        self._shardings = shardings
        self._sharding_fn = sharding_fn
        self._stats = {"transfers": 0, "put_dispatch_s": 0.0}

    def _put(self, host_batch):
        t0 = time.perf_counter()
        if self._shardings is None and self._sharding_fn is not None:
            self._shardings = self._sharding_fn(host_batch)
        if self._shardings is None:
            out = jax.device_put(host_batch)
        else:
            donate = (jax.tree.map(lambda x: isinstance(x, jax.Array),
                                   host_batch)
                      if self.donate else False)
            out = jax.device_put(host_batch, self._shardings, donate=donate)
        self._stats["transfers"] += 1
        self._stats["put_dispatch_s"] += time.perf_counter() - t0
        return out

    def __iter__(self):
        it = iter(self.loader)
        buf: "collections.deque" = collections.deque()
        try:
            while True:
                while len(buf) < self.depth:
                    host = next(it)
                    state = self.loader.state()   # state paired to `host`
                    buf.append((self._put(host), state))
                yield buf.popleft()
        finally:
            it.close()

    def stats(self) -> Dict[str, Any]:
        return dict(self._stats)
