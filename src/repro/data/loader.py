"""Sharded, deterministic, resumable loader: platform checkout -> device
batches.

Feed it a materialized :class:`~repro.core.dataset.Snapshot` or — the
preferred, allocation-free path — a lazy
:class:`~repro.core.dataset.CheckoutPlan` straight from
``Platform.open(...).dataset(name).plan(where=...)``: the loader only needs
the ``record_ids`` / ``read`` / ``content_digest`` read surface, which a
plan streams from the manifest without materializing a snapshot or
registering lineage for every restart.

This is the handoff between the paper's data plane and the TPU fleet:

- **Deterministic order**: records are ordered by a seeded hash of
  (record_id, epoch); every data shard slices the same global order, so a
  global batch is a pure function of (snapshot digest, epoch, step) — the
  property that makes checkpoint/restart exact (no skipped/duplicated data
  after preemption).
- **Sharded**: shard ``i`` of ``n`` reads records where
  ``order_index % n == i`` — in a multi-host job each host feeds only its
  slice and ``jax.make_array_from_process_local_data`` assembles the global
  array; single-process here, we assemble directly with ``device_put``.
- **Resumable**: ``state()`` is a tiny dict (snapshot digest, epoch, step)
  stored inside checkpoints; ``restore()`` seeks exactly there.
- **Straggler-tolerant**: a prefetch thread with a bounded queue rides over
  slow CAS reads; a timeout surfaces stuck shards instead of hanging the
  step loop.
"""

from __future__ import annotations

import hashlib
import queue
import threading
from typing import Any, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from typing import Union

from ..core.dataset import CheckoutPlan, Snapshot
from .components import decode_packed

__all__ = ["ShardedSnapshotLoader", "LoaderState"]

SnapshotLike = Union[Snapshot, CheckoutPlan]

LoaderState = Dict[str, Any]


def _order(record_ids: List[str], epoch: int, seed: int) -> List[str]:
    def key(rid: str) -> str:
        return hashlib.sha256(f"{seed}:{epoch}:{rid}".encode()).hexdigest()

    return sorted(record_ids, key=key)


class ShardedSnapshotLoader:
    def __init__(
        self,
        snapshot: SnapshotLike,
        batch_size: int,
        seq_len: int,
        shard_id: int = 0,
        n_shards: int = 1,
        seed: int = 0,
        prefetch: int = 2,
        timeout_s: float = 60.0,
    ):
        assert batch_size % n_shards == 0
        self.snapshot = snapshot
        self.batch = batch_size
        self.local_batch = batch_size // n_shards
        self.seq_len = seq_len
        self.shard_id = shard_id
        self.n_shards = n_shards
        self.seed = seed
        self.prefetch = prefetch
        self.timeout_s = timeout_s
        self.epoch = 0
        self.step = 0
        self._content = snapshot.content_digest()

    # ---------------------------------------------------------------- state

    def state(self) -> LoaderState:
        return {"snapshot_content": self._content, "epoch": self.epoch,
                "step": self.step, "seed": self.seed}

    def restore(self, state: LoaderState) -> None:
        if state["snapshot_content"] != self._content:
            raise ValueError(
                "loader restore onto a different snapshot: "
                f"{state['snapshot_content'][:12]} != {self._content[:12]} "
                "(lineage mismatch — refusing silent data drift)")
        self.epoch = int(state["epoch"])
        self.step = int(state["step"])
        self.seed = int(state["seed"])

    # ---------------------------------------------------------------- batches

    def _epoch_order(self, epoch: int) -> List[str]:
        return _order(self.snapshot.record_ids(), epoch, self.seed)

    def _read(self, rid: str) -> Dict[str, np.ndarray]:
        tokens, segments, positions = decode_packed(self.snapshot.read(rid))
        L = self.seq_len
        return {
            "tokens": tokens[:L], "labels": tokens[1:L + 1],
            "segments": segments[:L], "positions": positions[:L],
        }

    def next_batch(self) -> Dict[str, np.ndarray]:
        """The local (per-shard) slice of global batch ``self.step``."""
        order = self._epoch_order(self.epoch)
        per_epoch = len(order) // self.batch     # drop ragged tail
        if per_epoch == 0:
            raise ValueError("snapshot smaller than one global batch")
        step_in_epoch = self.step % per_epoch
        if self.step and step_in_epoch == 0:
            self.epoch += 1
            order = self._epoch_order(self.epoch)
        base = step_in_epoch * self.batch
        rows = []
        for j in range(self.local_batch):
            global_idx = base + self.shard_id + j * self.n_shards
            rows.append(self._read(order[global_idx]))
        self.step += 1
        out = {k: np.stack([r[k] for r in rows]) for k in rows[0]}
        # mask labels at padding (segment -1)
        out["labels"] = np.where(out["segments"] >= 0, out["labels"], -1)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def worker():
            while not stop.is_set():
                try:
                    q.put(self.next_batch(), timeout=1.0)
                except queue.Full:
                    continue
                except Exception as e:  # surface errors to the consumer
                    q.put(e)
                    return

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                item = q.get(timeout=self.timeout_s)
                if isinstance(item, Exception):
                    raise item
                yield item
        finally:
            stop.set()

    # ---------------------------------------------------------------- device

    def device_batch(self, batch: Dict[str, np.ndarray], mesh, specs
                     ) -> Dict[str, jnp.ndarray]:
        """Lay a host batch onto the mesh per the given PartitionSpecs."""
        from jax.sharding import NamedSharding

        return {
            k: jax.device_put(v, NamedSharding(mesh, specs[k]))
            for k, v in batch.items()
        }
