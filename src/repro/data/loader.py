"""Sharded, deterministic, resumable loader: platform checkout -> device
batches.

Feed it a materialized :class:`~repro.core.dataset.Snapshot` or — the
preferred, allocation-free path — a lazy
:class:`~repro.core.dataset.CheckoutPlan` straight from
``Platform.open(...).dataset(name).plan(where=...)``: the loader only needs
the ``record_ids`` / ``read`` / ``content_digest`` read surface, which a
plan streams from the manifest without materializing a snapshot or
registering lineage for every restart.

This is the handoff between the paper's data plane and the TPU fleet:

- **Deterministic order**: records are ordered by a seeded hash of
  (record_id, epoch); every data shard slices the same global order, so a
  global batch is a pure function of (snapshot digest, epoch, step) — the
  property that makes checkpoint/restart exact (no skipped/duplicated data
  after preemption).
- **Sharded**: shard ``i`` of ``n`` reads records where
  ``order_index % n == i`` — in a multi-host job each host feeds only its
  slice and ``jax.make_array_from_process_local_data`` assembles the global
  array; single-process here, we assemble directly with ``device_put``.
- **Resumable**: ``state()`` is a tiny dict (snapshot digest, epoch, step)
  stored inside checkpoints; ``restore()`` seeks exactly there.
- **Straggler-tolerant**: a prefetch thread with a bounded queue rides over
  slow CAS reads; a timeout surfaces stuck shards instead of hanging the
  step loop.
"""

from __future__ import annotations

import hashlib
import queue
import threading
from typing import Any, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from typing import Union

from ..core.dataset import CheckoutPlan, Snapshot
from .components import decode_packed

__all__ = ["ShardedSnapshotLoader", "LoaderState"]

SnapshotLike = Union[Snapshot, CheckoutPlan]

LoaderState = Dict[str, Any]


def _order(record_ids: List[str], epoch: int, seed: int) -> List[str]:
    """Reference epoch ordering — records sorted by seeded per-record hash.

    Kept as the executable spec: :func:`_order_fast` must stay bit-identical
    to this (the golden determinism suite pins it), or existing checkpoints
    would silently restore onto different batch streams.
    """
    def key(rid: str) -> str:
        return hashlib.sha256(f"{seed}:{epoch}:{rid}".encode()).hexdigest()

    return sorted(record_ids, key=key)


def _order_fast(record_ids: List[str], epoch: int, seed: int) -> List[str]:
    """Same permutation as :func:`_order`, computed vectorized.

    Hashes every id in one pass (the sha256 per record is load-bearing —
    it IS the ordering key), then argsorts the packed digest matrix with
    ``np.lexsort``.  Sorting by raw digest bytes equals sorting by
    ``hexdigest()`` because hex encoding is monotone bytewise; lexsort over
    the four big-endian u64 columns equals bytewise comparison of the
    32-byte digests, and both sorts are stable, so ties (impossible for
    distinct ids in practice) break identically.
    """
    if not record_ids:
        return []
    prefix = f"{seed}:{epoch}:".encode()
    sha = hashlib.sha256
    digests = b"".join(sha(prefix + rid.encode()).digest()
                       for rid in record_ids)
    cols = np.frombuffer(digests, dtype=">u8").reshape(-1, 4)
    perm = np.lexsort((cols[:, 3], cols[:, 2], cols[:, 1], cols[:, 0]))
    return [record_ids[i] for i in perm]


class ShardedSnapshotLoader:
    def __init__(
        self,
        snapshot: SnapshotLike,
        batch_size: int,
        seq_len: int,
        shard_id: int = 0,
        n_shards: int = 1,
        seed: int = 0,
        prefetch: int = 2,
        timeout_s: float = 60.0,
        cache_epoch_orders: bool = True,
    ):
        assert batch_size % n_shards == 0
        self.snapshot = snapshot
        self.batch = batch_size
        self.local_batch = batch_size // n_shards
        self.seq_len = seq_len
        self.shard_id = shard_id
        self.n_shards = n_shards
        self.seed = seed
        self.prefetch = prefetch
        self.timeout_s = timeout_s
        self.epoch = 0
        self.step = 0
        self._content = snapshot.content_digest()
        # ``cache_epoch_orders=False`` restores the pre-cache behaviour
        # (recompute the permutation every batch) — benchmark baseline only.
        self.cache_epoch_orders = cache_epoch_orders
        self._ids: Optional[List[str]] = None
        self._order_cache: Dict[tuple, List[str]] = {}

    # ---------------------------------------------------------------- state

    def state(self) -> LoaderState:
        return {"snapshot_content": self._content, "epoch": self.epoch,
                "step": self.step, "seed": self.seed}

    def restore(self, state: LoaderState) -> None:
        if state["snapshot_content"] != self._content:
            raise ValueError(
                "loader restore onto a different snapshot: "
                f"{state['snapshot_content'][:12]} != {self._content[:12]} "
                "(lineage mismatch — refusing silent data drift)")
        self.epoch = int(state["epoch"])
        self.step = int(state["step"])
        self.seed = int(state["seed"])

    # ---------------------------------------------------------------- batches

    def _record_ids(self) -> List[str]:
        if self._ids is None:
            self._ids = list(self.snapshot.record_ids())
        return self._ids

    def _epoch_order(self, epoch: int) -> List[str]:
        """Deterministic epoch permutation, computed once per (epoch, seed).

        The per-batch cost drops from O(N) hashing + O(N log N) sorting to
        a dict hit; ordering stays bit-identical to :func:`_order` (golden
        tests), so checkpoints restore onto identical batch streams.
        """
        if not self.cache_epoch_orders:
            return _order(self._record_ids(), epoch, self.seed)
        key = (epoch, self.seed)
        order = self._order_cache.get(key)
        if order is None:
            order = _order_fast(self._record_ids(), epoch, self.seed)
            # keep the current and previous epoch only (restore() can step
            # back); anything older is dead weight
            self._order_cache = {
                k: v for k, v in self._order_cache.items()
                if k[0] >= epoch - 1 and k[1] == self.seed}
            self._order_cache[key] = order
        return order

    def _decode_row(self, payload: bytes) -> Dict[str, np.ndarray]:
        tokens, segments, positions = decode_packed(payload)
        L = self.seq_len
        return {
            "tokens": tokens[:L], "labels": tokens[1:L + 1],
            "segments": segments[:L], "positions": positions[:L],
        }

    def _read(self, rid: str) -> Dict[str, np.ndarray]:
        return self._decode_row(self.snapshot.read(rid))

    def _read_rows(self, rids: List[str]) -> List[Dict[str, np.ndarray]]:
        reader = getattr(self.snapshot, "read_batch", None)
        if reader is not None:
            return [self._decode_row(buf) for buf in reader(rids)]
        return [self._read(rid) for rid in rids]

    def next_batch(self) -> Dict[str, np.ndarray]:
        """The local (per-shard) slice of global batch ``self.step``."""
        order = self._epoch_order(self.epoch)
        per_epoch = len(order) // self.batch     # drop ragged tail
        if per_epoch == 0:
            raise ValueError("snapshot smaller than one global batch")
        step_in_epoch = self.step % per_epoch
        if self.step and step_in_epoch == 0:
            self.epoch += 1
            order = self._epoch_order(self.epoch)
        base = step_in_epoch * self.batch
        rids = [order[base + self.shard_id + j * self.n_shards]
                for j in range(self.local_batch)]
        rows = self._read_rows(rids)
        self.step += 1
        out = {k: np.stack([r[k] for r in rows]) for k in rows[0]}
        # mask labels at padding (segment -1)
        out["labels"] = np.where(out["segments"] >= 0, out["labels"], -1)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def _put(item) -> bool:
            # Never block forever on a full queue: the consumer may be gone
            # (generator closed / errored), so re-check ``stop`` between
            # bounded put attempts instead of deadlocking the worker.
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.1)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            while not stop.is_set():
                try:
                    item = self.next_batch()
                except Exception as e:  # surface errors to the consumer
                    _put(e)
                    return
                # the batch is computed exactly once, then offered until it
                # lands (the old put-or-recompute loop silently dropped a
                # batch each time the queue was full at the wrong moment)
                if not _put(item):
                    return

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                item = q.get(timeout=self.timeout_s)
                if isinstance(item, Exception):
                    raise item
                yield item
        finally:
            stop.set()
            # drain so a worker mid-``put`` wakes immediately, then reap it
            try:
                while True:
                    q.get_nowait()
            except queue.Empty:
                pass
            t.join(timeout=5.0)

    # ---------------------------------------------------------------- device

    def device_batch(self, batch: Dict[str, np.ndarray], mesh, specs
                     ) -> Dict[str, jnp.ndarray]:
        """Lay a host batch onto the mesh per the given PartitionSpecs."""
        from jax.sharding import NamedSharding

        return {
            k: jax.device_put(v, NamedSharding(mesh, specs[k]))
            for k, v in batch.items()
        }
