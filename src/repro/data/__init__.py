from .components import (ByteTokenizer, DedupComponent,
                         LengthFilterComponent, PackComponent,
                         SplitComponent, TokenizeComponent, decode_packed)
from .loader import DeviceFeed, LoaderState, ShardedSnapshotLoader

__all__ = [
    "ByteTokenizer", "DedupComponent", "LengthFilterComponent",
    "PackComponent", "SplitComponent", "TokenizeComponent", "decode_packed",
    "DeviceFeed", "LoaderState", "ShardedSnapshotLoader",
]
