from .components import (ByteTokenizer, DedupComponent,
                         LengthFilterComponent, PackComponent,
                         SplitComponent, TokenizeComponent, decode_packed)
from .loader import LoaderState, ShardedSnapshotLoader

__all__ = [
    "ByteTokenizer", "DedupComponent", "LengthFilterComponent",
    "PackComponent", "SplitComponent", "TokenizeComponent", "decode_packed",
    "LoaderState", "ShardedSnapshotLoader",
]
